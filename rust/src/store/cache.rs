//! The caching tier: a read-through chunk cache that sits between any
//! [`Backend`] and the [`EntryReader`] handed to senders / DT-local
//! resolution / GFN — the tf.data-style "caching + prefetching between
//! storage and consumer" layer that makes a remote-backed bucket fast.
//!
//! Objects are cached as `chunk_bytes`-aligned chunks keyed by
//! `(bucket, object, chunk index)`, so shard members extracted from the
//! same archive share cached chunks, and a partially read object costs
//! only the chunks actually touched. Capacity is bytes
//! (`GetBatchConfig::cache_bytes`) with strict LRU eviction. On a miss the
//! cache reads the missing chunk *plus the next `readahead_chunks` chunks*
//! through one sequential ranged read of the inner backend (sequential
//! read-ahead — the access pattern of TAR assembly), inserting them
//! chunk-by-chunk so transient residency beyond the cache's own accounting
//! stays O(chunk_bytes).

use std::collections::{BTreeMap, HashMap};
use std::io;
use std::sync::{Arc, Mutex};

use crate::metrics::GetBatchMetrics;

use super::engine::{Backend, ChunkSource, EntryReader, StoreError};

type ChunkKey = (String, String, u64);

struct CacheSlot {
    data: Arc<Vec<u8>>,
    /// LRU stamp; also the key into `CacheState::lru`.
    seq: u64,
}

#[derive(Default)]
struct CacheState {
    map: HashMap<ChunkKey, CacheSlot>,
    /// Recency order: oldest stamp first.
    lru: BTreeMap<u64, ChunkKey>,
    /// Object lengths learned at open time — warm opens (and fully cached
    /// objects whose backend is unreachable) skip the inner `size` probe.
    lens: HashMap<(String, String), u64>,
    bytes: u64,
    seq: u64,
}

/// Shared per-node chunk cache (one per target; every cached bucket stack
/// on the node draws from the same byte budget).
pub struct ChunkCache {
    capacity: u64,
    chunk_bytes: usize,
    state: Mutex<CacheState>,
    metrics: Option<Arc<GetBatchMetrics>>,
    pub hits: crate::metrics::Counter,
    pub misses: crate::metrics::Counter,
    pub evictions: crate::metrics::Counter,
}

impl ChunkCache {
    pub fn new(
        capacity: u64,
        chunk_bytes: usize,
        metrics: Option<Arc<GetBatchMetrics>>,
    ) -> ChunkCache {
        ChunkCache {
            capacity,
            chunk_bytes: chunk_bytes.max(1),
            state: Mutex::new(CacheState::default()),
            metrics,
            hits: Default::default(),
            misses: Default::default(),
            evictions: Default::default(),
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn chunk_bytes(&self) -> usize {
        self.chunk_bytes
    }

    pub fn resident_bytes(&self) -> u64 {
        self.state.lock().unwrap().bytes
    }

    fn get(&self, bucket: &str, obj: &str, idx: u64) -> Option<Arc<Vec<u8>>> {
        let mut st = self.state.lock().unwrap();
        let key = (bucket.to_string(), obj.to_string(), idx);
        if let Some(slot) = st.map.get(&key) {
            let (old, data) = (slot.seq, Arc::clone(&slot.data));
            st.lru.remove(&old);
            st.seq += 1;
            let seq = st.seq;
            st.lru.insert(seq, key.clone());
            st.map.get_mut(&key).expect("slot present").seq = seq;
            self.hits.inc();
            if let Some(m) = &self.metrics {
                m.cache_hits.inc();
            }
            Some(data)
        } else {
            self.misses.inc();
            if let Some(m) = &self.metrics {
                m.cache_misses.inc();
            }
            None
        }
    }

    fn insert(&self, bucket: &str, obj: &str, idx: u64, data: Arc<Vec<u8>>) {
        let len = data.len() as u64;
        if len > self.capacity {
            return; // larger than the whole cache: not cacheable
        }
        let mut st = self.state.lock().unwrap();
        let key = (bucket.to_string(), obj.to_string(), idx);
        if let Some(old) = st.map.remove(&key) {
            st.lru.remove(&old.seq);
            st.bytes -= old.data.len() as u64;
        }
        // Strict LRU eviction down to capacity.
        while st.bytes + len > self.capacity {
            let (&oldest, _) = st.lru.iter().next().expect("bytes > 0 implies lru non-empty");
            let victim = st.lru.remove(&oldest).expect("oldest present");
            let slot = st.map.remove(&victim).expect("lru and map in sync");
            st.bytes -= slot.data.len() as u64;
            self.evictions.inc();
            if let Some(m) = &self.metrics {
                m.cache_evictions.inc();
            }
        }
        st.seq += 1;
        let seq = st.seq;
        st.lru.insert(seq, key.clone());
        st.bytes += len;
        st.map.insert(key, CacheSlot { data, seq });
        if let Some(m) = &self.metrics {
            m.cache_resident_bytes.set(st.bytes as i64);
        }
    }

    /// Object length learned by a previous open, if still valid.
    fn len_of(&self, bucket: &str, obj: &str) -> Option<u64> {
        self.state.lock().unwrap().lens.get(&(bucket.to_string(), obj.to_string())).copied()
    }

    fn remember_len(&self, bucket: &str, obj: &str, len: u64) {
        self.state.lock().unwrap().lens.insert((bucket.to_string(), obj.to_string()), len);
    }

    /// Drop every cached chunk of one object (after PUT/DELETE).
    pub fn invalidate_object(&self, bucket: &str, obj: &str) {
        let mut st = self.state.lock().unwrap();
        st.lens.remove(&(bucket.to_string(), obj.to_string()));
        let victims: Vec<ChunkKey> = st
            .map
            .keys()
            .filter(|(b, o, _)| b == bucket && o == obj)
            .cloned()
            .collect();
        for key in victims {
            if let Some(slot) = st.map.remove(&key) {
                st.lru.remove(&slot.seq);
                st.bytes -= slot.data.len() as u64;
            }
        }
        if let Some(m) = &self.metrics {
            m.cache_resident_bytes.set(st.bytes as i64);
        }
    }
}

/// A [`Backend`] decorator routing all reads through a [`ChunkCache`];
/// writes and deletes pass through and invalidate. Wrap a
/// [`RemoteBackend`](super::remote::RemoteBackend) to hide network latency,
/// or a local backend to serve a hot working set from memory.
///
/// Failover transparency: the cache composes over a multi-endpoint remote
/// backend unchanged — a fill's inner ranged read may fail over (or resume
/// mid-stream on another endpoint) underneath it, and under the endpoint
/// set's contract (every endpoint fronts the same underlying store) the
/// inserted chunks are byte-identical whichever endpoint served them. Note
/// the remote tier's EOF CRC check covers only whole-object streams — a
/// ranged fill cannot be checked against the whole-object sidecar — so
/// listing *divergent* replicas as endpoints is outside the contract on
/// this path too (see `store::remote`).
pub struct CachedBackend {
    inner: Arc<dyn Backend>,
    cache: Arc<ChunkCache>,
    readahead_chunks: usize,
}

impl CachedBackend {
    pub fn new(
        inner: Arc<dyn Backend>,
        cache: Arc<ChunkCache>,
        readahead_chunks: usize,
    ) -> CachedBackend {
        CachedBackend { inner, cache, readahead_chunks }
    }

    fn source(&self, bucket: &str, obj: &str, base: u64, obj_len: u64) -> CacheSource {
        CacheSource {
            inner: Arc::clone(&self.inner),
            cache: Arc::clone(&self.cache),
            bucket: bucket.to_string(),
            obj: obj.to_string(),
            base,
            obj_len,
            readahead_chunks: self.readahead_chunks,
        }
    }
}

impl CachedBackend {
    /// The object's length: from the cache's remembered lengths when warm
    /// (no inner round trip — a fully cached object stays readable even if
    /// the inner backend is unreachable), read through on first open.
    fn object_len(&self, bucket: &str, obj: &str) -> Result<u64, StoreError> {
        if let Some(len) = self.cache.len_of(bucket, obj) {
            return Ok(len);
        }
        let len = self.inner.size(bucket, obj)?;
        self.cache.remember_len(bucket, obj, len);
        Ok(len)
    }
}

impl Backend for CachedBackend {
    fn open_entry(&self, bucket: &str, obj: &str) -> Result<EntryReader, StoreError> {
        let len = self.object_len(bucket, obj)?;
        Ok(EntryReader::from_source(Box::new(self.source(bucket, obj, 0, len)), len))
    }

    fn open_entry_range(
        &self,
        bucket: &str,
        obj: &str,
        offset: u64,
        len: u64,
    ) -> Result<EntryReader, StoreError> {
        let total = self.object_len(bucket, obj)?;
        if offset.saturating_add(len) > total {
            return Err(StoreError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("range {offset}+{len} past EOF ({total}) in {bucket}/{obj}"),
            )));
        }
        Ok(EntryReader::from_source(Box::new(self.source(bucket, obj, offset, total)), len))
    }

    fn put(&self, bucket: &str, obj: &str, data: &[u8]) -> Result<(), StoreError> {
        let r = self.inner.put(bucket, obj, data);
        self.cache.invalidate_object(bucket, obj);
        r
    }

    fn exists(&self, bucket: &str, obj: &str) -> bool {
        self.inner.exists(bucket, obj)
    }

    fn size(&self, bucket: &str, obj: &str) -> Result<u64, StoreError> {
        self.inner.size(bucket, obj)
    }

    fn delete(&self, bucket: &str, obj: &str) -> Result<(), StoreError> {
        let r = self.inner.delete(bucket, obj);
        self.cache.invalidate_object(bucket, obj);
        r
    }

    fn list(&self, bucket: &str) -> Result<Vec<String>, StoreError> {
        self.inner.list(bucket)
    }

    fn content_crc(&self, bucket: &str, obj: &str) -> Option<u32> {
        self.inner.content_crc(bucket, obj)
    }
}

/// Source serving entry bytes from object-aligned cached chunks,
/// read-through to the inner backend on a miss.
struct CacheSource {
    inner: Arc<dyn Backend>,
    cache: Arc<ChunkCache>,
    bucket: String,
    obj: String,
    /// Entry span start within the object (0 for whole objects).
    base: u64,
    /// Full object length (chunk alignment is object-relative so shard
    /// members share chunks).
    obj_len: u64,
    readahead_chunks: usize,
}

impl CacheSource {
    /// Read-through fill on a miss: one sequential inner read covering the
    /// missing chunk plus up to `readahead_chunks` successors, inserted
    /// chunk-by-chunk (transient residency stays O(chunk_bytes)).
    fn fill(&self, idx: u64) -> Result<Arc<Vec<u8>>, StoreError> {
        let cb = self.cache.chunk_bytes() as u64;
        let last_idx = if self.obj_len == 0 { 0 } else { (self.obj_len - 1) / cb };
        let end_idx = idx.saturating_add(self.readahead_chunks as u64).min(last_idx);
        let start = idx * cb;
        let span = (self.obj_len.min((end_idx + 1) * cb)) - start;
        let mut reader = self.inner.open_entry_range(&self.bucket, &self.obj, start, span)?;
        let mut first: Option<Arc<Vec<u8>>> = None;
        for i in idx..=end_idx {
            let piece = Arc::new(reader.read_chunk(cb as usize)?);
            self.cache.insert(&self.bucket, &self.obj, i, Arc::clone(&piece));
            if i == idx {
                first = Some(piece);
            }
        }
        Ok(first.expect("loop covers idx"))
    }
}

impl ChunkSource for CacheSource {
    fn read_at(&mut self, pos: u64, buf: &mut [u8]) -> io::Result<usize> {
        let off = self.base + pos;
        if off >= self.obj_len || buf.is_empty() {
            return Ok(0);
        }
        let cb = self.cache.chunk_bytes() as u64;
        let idx = off / cb;
        let chunk = match self.cache.get(&self.bucket, &self.obj, idx) {
            Some(c) => c,
            None => self.fill(idx).map_err(io::Error::from)?,
        };
        let within = (off - idx * cb) as usize;
        if within >= chunk.len() {
            return Ok(0); // object shrank under the cache: reader surfaces EOF
        }
        let n = buf.len().min(chunk.len() - within);
        buf[..n].copy_from_slice(&chunk[within..within + n]);
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::local::LocalBackend;
    use std::path::PathBuf;

    fn setup(name: &str, cache_bytes: u64, chunk: usize, ra: usize) -> (CachedBackend, Arc<ChunkCache>, Arc<LocalBackend>, PathBuf) {
        let base = std::env::temp_dir().join(format!("gbcache-{}-{}", std::process::id(), name));
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&base).unwrap();
        let local = Arc::new(LocalBackend::open(&base, 2).unwrap());
        let cache = Arc::new(ChunkCache::new(cache_bytes, chunk, None));
        let cached = CachedBackend::new(
            Arc::clone(&local) as Arc<dyn Backend>,
            Arc::clone(&cache),
            ra,
        );
        (cached, cache, local, base)
    }

    fn payload(n: usize, seed: u32) -> Vec<u8> {
        (0..n as u32).map(|i| ((i.wrapping_mul(31).wrapping_add(seed)) % 251) as u8).collect()
    }

    #[test]
    fn cold_miss_then_warm_hit_byte_identical() {
        let (cached, cache, _local, base) = setup("warm", 1 << 20, 4 << 10, 0);
        let data = payload(50_000, 7);
        cached.put("b", "o", &data).unwrap();
        let cold = cached.open_entry("b", "o").unwrap().read_all().unwrap();
        assert_eq!(cold, data);
        let cold_misses = cache.misses.get();
        assert!(cold_misses > 0);
        let warm = cached.open_entry("b", "o").unwrap().read_all().unwrap();
        assert_eq!(warm, data);
        assert_eq!(cache.misses.get(), cold_misses, "warm read misses nothing");
        assert!(cache.hits.get() >= cold_misses, "every chunk re-served from cache");
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn lru_evicts_under_byte_pressure() {
        // 4 KiB chunks, 16 KiB cache → 4 resident chunks. Reading 10
        // distinct 4 KiB objects must evict, stay ≤ capacity, and still
        // serve every object byte-identically.
        let (cached, cache, _local, base) = setup("lru", 16 << 10, 4 << 10, 0);
        for i in 0..10 {
            cached.put("b", &format!("o{i}"), &payload(4 << 10, i)).unwrap();
        }
        for i in 0..10 {
            let got = cached.open_entry("b", &format!("o{i}")).unwrap().read_all().unwrap();
            assert_eq!(got, payload(4 << 10, i), "o{i} byte-identical through the cache");
        }
        assert!(cache.resident_bytes() <= cache.capacity());
        assert!(cache.evictions.get() >= 6, "evictions: {}", cache.evictions.get());
        // LRU order: the most recently read object is still resident.
        let before = cache.misses.get();
        let _ = cached.open_entry("b", "o9").unwrap().read_all().unwrap();
        assert_eq!(cache.misses.get(), before, "hottest object still cached");
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn readahead_prefetches_sequential_chunks() {
        // 8 chunks of 4 KiB; readahead 3 → the first touch fills chunks
        // 0..=3 in one inner read; touching chunk 1 next is a pure hit.
        let (cached, cache, _local, base) = setup("ra", 1 << 20, 4 << 10, 3);
        let data = payload(32 << 10, 3);
        cached.put("b", "o", &data).unwrap();
        let mut r = cached.open_entry("b", "o").unwrap();
        let first = r.read_chunk(4 << 10).unwrap();
        assert_eq!(first, &data[..4 << 10]);
        assert_eq!(cache.misses.get(), 1, "single miss triggers the fill");
        assert_eq!(cache.resident_bytes(), 4 * (4 << 10), "3 chunks prefetched");
        let second = r.read_chunk(4 << 10).unwrap();
        assert_eq!(second, &data[4 << 10..8 << 10]);
        assert_eq!(cache.misses.get(), 1, "read-ahead made chunk 1 a hit");
        assert!(cache.hits.get() >= 1);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn warm_object_readable_without_inner_backend() {
        let (cached, _cache, local, base) = setup("warmlen", 1 << 20, 4 << 10, 1);
        let data = payload(12 << 10, 4);
        cached.put("b", "o", &data).unwrap();
        assert_eq!(cached.open_entry("b", "o").unwrap().read_all().unwrap(), data);
        // Remove the object behind the cache's back: a fully warm object
        // must still open (remembered length) and serve every byte from
        // cached chunks, with zero inner round trips.
        local.delete("b", "o").unwrap();
        assert_eq!(cached.open_entry("b", "o").unwrap().read_all().unwrap(), data);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn put_invalidates_cached_chunks() {
        let (cached, cache, _local, base) = setup("inval", 1 << 20, 4 << 10, 1);
        cached.put("b", "o", &payload(12 << 10, 1)).unwrap();
        let _ = cached.open_entry("b", "o").unwrap().read_all().unwrap();
        assert!(cache.resident_bytes() > 0);
        let fresh = payload(12 << 10, 2);
        cached.put("b", "o", &fresh).unwrap();
        assert_eq!(cache.resident_bytes(), 0, "overwrite dropped stale chunks");
        assert_eq!(cached.open_entry("b", "o").unwrap().read_all().unwrap(), fresh);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn ranged_members_share_object_chunks() {
        // Two spans of the same object: the second lands on chunks the
        // first already cached (object-aligned keys).
        let (cached, cache, _local, base) = setup("spans", 1 << 20, 4 << 10, 0);
        let data = payload(16 << 10, 9);
        cached.put("b", "o", &data).unwrap();
        let a = cached.open_entry_range("b", "o", 0, 8 << 10).unwrap().read_all().unwrap();
        assert_eq!(a, &data[..8 << 10]);
        let miss_after_a = cache.misses.get();
        let b = cached.open_entry_range("b", "o", 1024, 4096).unwrap().read_all().unwrap();
        assert_eq!(b, &data[1024..1024 + 4096]);
        assert_eq!(cache.misses.get(), miss_after_a, "overlapping span fully cached");
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn zero_length_objects_pass_through() {
        let (cached, _cache, _local, base) = setup("zero", 1 << 20, 4 << 10, 2);
        cached.put("b", "empty", b"").unwrap();
        let r = cached.open_entry("b", "empty").unwrap();
        assert!(r.is_empty());
        assert_eq!(r.read_all().unwrap(), b"");
        std::fs::remove_dir_all(base).unwrap();
    }
}
