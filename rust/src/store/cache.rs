//! The caching tier: a read-through chunk cache that sits between any
//! [`Backend`] and the [`EntryReader`] handed to senders / DT-local
//! resolution / GFN — the tf.data-style "caching + prefetching between
//! storage and consumer" layer that makes a remote-backed bucket fast.
//!
//! Objects are cached as `chunk_bytes`-aligned chunks keyed by
//! `(bucket, object, version, chunk index)`, so shard members extracted
//! from the same archive share cached chunks, and a partially read object
//! costs only the chunks actually touched. Capacity is bytes
//! (`GetBatchConfig::cache_bytes`) with strict LRU eviction. On a miss the
//! cache reads the missing chunk *plus the next `readahead_chunks` chunks*
//! through one sequential ranged read of the inner backend (sequential
//! read-ahead — the access pattern of TAR assembly).
//!
//! **Coherence.** The `version` in the chunk key is the object's monotonic
//! write generation (stamped by the local tier at PUT, carried over HTTP
//! via `x-getbatch-version`). Every open pins the version it observed; all
//! chunks it reads or fills are keyed by that pin, so a single read can
//! never interleave bytes of two versions — the fill path confirms the
//! version the bytes came from (the fill reader's own observed version
//! when the inner tier surfaces one, a separate re-probe otherwise) and
//! refuses to serve/insert on a mismatch (sound because the local tier
//! guarantees bytes are never newer than the version a later — or
//! same-handle — lookup reports). Observing a newer
//! version eagerly evicts every older version's chunks
//! (`cache_stale_evictions_total`). Remembered per-object metadata
//! (length + version) is trusted for `coherence_grace_ms` since its last
//! validation; past the grace an open re-probes the inner backend, which
//! is what keeps a node correct when it *missed* an invalidation
//! broadcast. Within the grace, coherence is the broadcast's job
//! (`/v1/invalidate` → [`ChunkCache::invalidate_object`]).

use std::collections::{BTreeMap, HashMap};
use std::io;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::metrics::GetBatchMetrics;
use crate::util::clock::{Clock, RealClock};

use super::engine::{Backend, ChunkSource, EntryReader, ObjectStat, StoreError};

/// `(bucket, object, version, chunk index)`; version 0 = unversioned
/// (inner tier reported no generation — LRU-convergent legacy behavior).
type ChunkKey = (String, String, u64, u64);

struct CacheSlot {
    data: Arc<Vec<u8>>,
    /// LRU stamp; also the key into `CacheState::lru`.
    seq: u64,
    /// Pinned by the prefetch path for an imminent demand read: pinned
    /// slots are skipped by ordinary LRU eviction (a demand fill evicts
    /// them only as a last resort, and a prefetch fill never does) and
    /// unpin on their first demand hit.
    pinned: bool,
}

/// Who is inserting a chunk: the demand path fills inline on a read miss;
/// the prefetch path fills ahead of need (pinned, and forbidden from
/// evicting other pinned chunks to make room).
#[derive(Clone, Copy, PartialEq)]
enum FillKind {
    Demand,
    Prefetch,
}

/// Remembered per-object metadata: warm opens (and fully cached objects
/// whose backend is unreachable) skip the inner probe while `validated`
/// is within the coherence grace.
struct ObjMeta {
    len: u64,
    version: u64,
    /// PUT-time CRC-32 sidecar learned by the same probe, when the inner
    /// tier stores one — kept so `stat` answers without a second probe.
    crc: Option<u32>,
    /// Stamp on the cache's clock ([`ChunkCache::with_clock`]) — compared
    /// against the same clock by `remembered`, so grace windows age in
    /// virtual time under the scale simulator.
    validated_ns: u64,
}

#[derive(Default)]
struct CacheState {
    map: HashMap<ChunkKey, CacheSlot>,
    /// Recency order: oldest stamp first.
    lru: BTreeMap<u64, ChunkKey>,
    lens: HashMap<(String, String), ObjMeta>,
    bytes: u64,
    seq: u64,
}

/// Shared per-node chunk cache (one per target; every cached bucket stack
/// on the node draws from the same byte budget).
pub struct ChunkCache {
    capacity: u64,
    chunk_bytes: usize,
    state: Mutex<CacheState>,
    metrics: Option<Arc<GetBatchMetrics>>,
    /// Coherence-grace aging runs on this clock (real in production,
    /// virtual under the scale simulator).
    clock: Arc<dyn Clock>,
    pub hits: crate::metrics::Counter,
    pub misses: crate::metrics::Counter,
    pub evictions: crate::metrics::Counter,
    /// Chunks dropped because a newer object version was observed (or the
    /// object was invalidated/deleted) — coherence work, distinct from
    /// capacity-driven LRU `evictions`.
    pub stale_evictions: crate::metrics::Counter,
    /// Invalidation events processed (local write-through or received
    /// `/v1/invalidate` broadcast).
    pub invalidations: crate::metrics::Counter,
    /// Fill origin split: chunks inserted by the demand (read-miss) path
    /// vs the prefetch path.
    pub fills_demand: crate::metrics::Counter,
    pub fills_prefetch: crate::metrics::Counter,
    /// Demand hits that landed on a still-pinned prefetched chunk — the
    /// prefetch did its job.
    pub prefetch_hits: crate::metrics::Counter,
    /// Prefetched chunks dropped (evicted, staled, invalidated, or never
    /// admitted for lack of unpinned room) before any demand read.
    pub prefetch_wasted: crate::metrics::Counter,
}

impl ChunkCache {
    pub fn new(
        capacity: u64,
        chunk_bytes: usize,
        metrics: Option<Arc<GetBatchMetrics>>,
    ) -> ChunkCache {
        ChunkCache::with_clock(capacity, chunk_bytes, metrics, RealClock::new())
    }

    /// Cache on an explicit clock (the simulation-harness entry point; the
    /// production constructor above pins the real clock).
    pub fn with_clock(
        capacity: u64,
        chunk_bytes: usize,
        metrics: Option<Arc<GetBatchMetrics>>,
        clock: Arc<dyn Clock>,
    ) -> ChunkCache {
        ChunkCache {
            capacity,
            chunk_bytes: chunk_bytes.max(1),
            state: Mutex::new(CacheState::default()),
            metrics,
            clock,
            hits: Default::default(),
            misses: Default::default(),
            evictions: Default::default(),
            stale_evictions: Default::default(),
            invalidations: Default::default(),
            fills_demand: Default::default(),
            fills_prefetch: Default::default(),
            prefetch_hits: Default::default(),
            prefetch_wasted: Default::default(),
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn chunk_bytes(&self) -> usize {
        self.chunk_bytes
    }

    pub fn resident_bytes(&self) -> u64 {
        self.state.lock().unwrap().bytes
    }

    fn get(&self, bucket: &str, obj: &str, version: u64, idx: u64) -> Option<Arc<Vec<u8>>> {
        let mut st = self.state.lock().unwrap();
        let key = (bucket.to_string(), obj.to_string(), version, idx);
        if let Some(slot) = st.map.get(&key) {
            let (old, data, pinned) = (slot.seq, Arc::clone(&slot.data), slot.pinned);
            st.lru.remove(&old);
            st.seq += 1;
            let seq = st.seq;
            st.lru.insert(seq, key.clone());
            let slot = st.map.get_mut(&key).expect("slot present");
            slot.seq = seq;
            if pinned {
                // First demand read of a prefetched chunk: the prefetch
                // paid off. Unpin so the chunk ages out like any other.
                slot.pinned = false;
                self.prefetch_hits.inc();
                if let Some(m) = &self.metrics {
                    m.prefetch_hits.inc();
                }
            }
            self.hits.inc();
            if let Some(m) = &self.metrics {
                m.cache_hits.inc();
            }
            Some(data)
        } else {
            self.misses.inc();
            if let Some(m) = &self.metrics {
                m.cache_misses.inc();
            }
            None
        }
    }

    /// Whether a chunk is resident, with no side effects — no hit/miss
    /// accounting, no LRU touch, no unpin. The prefetch planner uses this
    /// to skip already-warm chunks without skewing the demand-path stats.
    fn contains(&self, bucket: &str, obj: &str, version: u64, idx: u64) -> bool {
        let st = self.state.lock().unwrap();
        st.map.contains_key(&(bucket.to_string(), obj.to_string(), version, idx))
    }

    /// Insert one chunk; returns whether it was admitted. Eviction is
    /// pin-aware: oldest *unpinned* chunks go first; a demand fill may
    /// evict pinned chunks as a last resort (capacity is a hard
    /// invariant), while a prefetch fill that finds nothing unpinned to
    /// evict drops the incoming chunk instead — speculative work never
    /// cannibalizes earlier speculation or the demand working set, and
    /// resident bytes never exceed `capacity`.
    fn insert(
        &self,
        bucket: &str,
        obj: &str,
        version: u64,
        idx: u64,
        data: Arc<Vec<u8>>,
        kind: FillKind,
    ) -> bool {
        let len = data.len() as u64;
        if len > self.capacity {
            if kind == FillKind::Prefetch {
                self.count_wasted(1);
            }
            return false; // larger than the whole cache: not cacheable
        }
        let mut st = self.state.lock().unwrap();
        let key = (bucket.to_string(), obj.to_string(), version, idx);
        if let Some(old) = st.map.remove(&key) {
            st.lru.remove(&old.seq);
            st.bytes -= old.data.len() as u64;
        }
        while st.bytes + len > self.capacity {
            let unpinned = st
                .lru
                .iter()
                .find(|&(_, k)| !st.map[k].pinned)
                .map(|(&s, k)| (s, k.clone()));
            let (vseq, vkey) = match unpinned {
                Some(v) => v,
                None if kind == FillKind::Prefetch => {
                    // Everything resident is pinned for imminent batches:
                    // this speculative chunk loses, not them.
                    drop(st);
                    self.count_wasted(1);
                    return false;
                }
                None => {
                    let (&s, k) = st.lru.iter().next().expect("bytes > 0 implies lru non-empty");
                    (s, k.clone())
                }
            };
            st.lru.remove(&vseq).expect("victim present");
            let slot = st.map.remove(&vkey).expect("lru and map in sync");
            st.bytes -= slot.data.len() as u64;
            if slot.pinned {
                self.count_wasted(1);
            }
            self.evictions.inc();
            if let Some(m) = &self.metrics {
                m.cache_evictions.inc();
            }
        }
        st.seq += 1;
        let seq = st.seq;
        st.lru.insert(seq, key.clone());
        st.bytes += len;
        st.map.insert(key, CacheSlot { data, seq, pinned: kind == FillKind::Prefetch });
        if let Some(m) = &self.metrics {
            m.cache_resident_bytes.set(st.bytes as i64);
        }
        match kind {
            FillKind::Demand => {
                self.fills_demand.inc();
                if let Some(m) = &self.metrics {
                    m.cache_fills_demand.inc();
                }
            }
            FillKind::Prefetch => {
                self.fills_prefetch.inc();
                if let Some(m) = &self.metrics {
                    m.cache_fills_prefetch.inc();
                }
            }
        }
        true
    }

    fn count_wasted(&self, n: u64) {
        self.prefetch_wasted.add(n);
        if let Some(m) = &self.metrics {
            m.prefetch_wasted.add(n);
        }
    }

    /// Drop the given chunks as *stale* (coherence, not capacity).
    fn drop_stale(&self, st: &mut CacheState, victims: Vec<ChunkKey>) {
        for key in victims {
            if let Some(slot) = st.map.remove(&key) {
                st.lru.remove(&slot.seq);
                st.bytes -= slot.data.len() as u64;
                if slot.pinned {
                    // A prefetched chunk staled (overwrite/invalidate)
                    // before any demand read consumed it.
                    self.count_wasted(1);
                }
                self.stale_evictions.inc();
                if let Some(m) = &self.metrics {
                    m.cache_stale_evictions.inc();
                }
            }
        }
        if let Some(m) = &self.metrics {
            m.cache_resident_bytes.set(st.bytes as i64);
        }
    }

    /// Remembered (len, version, crc) if validated within `grace`.
    fn remembered(
        &self,
        bucket: &str,
        obj: &str,
        grace: Duration,
    ) -> Option<(u64, u64, Option<u32>)> {
        let st = self.state.lock().unwrap();
        let now = self.clock.now_ns();
        st.lens
            .get(&(bucket.to_string(), obj.to_string()))
            .filter(|m| now.saturating_sub(m.validated_ns) <= grace.as_nanos() as u64)
            .map(|m| (m.len, m.version, m.crc))
    }

    /// Remembered (len, version, crc) regardless of age — the degraded path
    /// when the inner backend is unreachable at revalidation time.
    fn remembered_any(&self, bucket: &str, obj: &str) -> Option<(u64, u64, Option<u32>)> {
        let st = self.state.lock().unwrap();
        st.lens
            .get(&(bucket.to_string(), obj.to_string()))
            .map(|m| (m.len, m.version, m.crc))
    }

    /// Record freshly probed metadata. Observing a version makes every
    /// *other* version's chunks of this object unreachable garbage — drop
    /// them eagerly instead of letting them age out of LRU.
    pub fn observe(&self, bucket: &str, obj: &str, len: u64, version: u64, crc: Option<u32>) {
        let mut st = self.state.lock().unwrap();
        let prev = st.lens.insert(
            (bucket.to_string(), obj.to_string()),
            ObjMeta { len, version, crc, validated_ns: self.clock.now_ns() },
        );
        if version != 0 || prev.map(|m| m.version != 0).unwrap_or(false) {
            let victims: Vec<ChunkKey> = st
                .map
                .keys()
                .filter(|(b, o, v, _)| b == bucket && o == obj && *v != version)
                .cloned()
                .collect();
            if !victims.is_empty() {
                self.drop_stale(&mut st, victims);
            }
        }
    }

    /// Drop every cached chunk of one object, all versions (after a local
    /// PUT/DELETE through this stack, or a received `/v1/invalidate`
    /// broadcast).
    pub fn invalidate_object(&self, bucket: &str, obj: &str) {
        let mut st = self.state.lock().unwrap();
        st.lens.remove(&(bucket.to_string(), obj.to_string()));
        let victims: Vec<ChunkKey> = st
            .map
            .keys()
            .filter(|(b, o, _, _)| b == bucket && o == obj)
            .cloned()
            .collect();
        self.drop_stale(&mut st, victims);
        self.invalidations.inc();
        if let Some(m) = &self.metrics {
            m.cache_invalidations.inc();
        }
    }
}

/// A [`Backend`] decorator routing all reads through a [`ChunkCache`];
/// writes and deletes pass through and invalidate. Wrap a
/// [`RemoteBackend`](super::remote::RemoteBackend) to hide network latency,
/// or a local backend to serve a hot working set from memory.
///
/// Failover transparency: the cache composes over a multi-endpoint remote
/// backend unchanged — a fill's inner ranged read may fail over (or resume
/// mid-stream on another endpoint) underneath it, and under the endpoint
/// set's contract (every endpoint fronts the same underlying store) the
/// inserted chunks are byte-identical whichever endpoint served them. Note
/// the remote tier's EOF CRC check covers only whole-object streams — a
/// ranged fill cannot be checked against the whole-object sidecar — so
/// listing *divergent* replicas as endpoints is outside the contract on
/// this path too (see `store::remote`).
pub struct CachedBackend {
    inner: Arc<dyn Backend>,
    cache: Arc<ChunkCache>,
    readahead_chunks: usize,
    /// How long remembered (len, version) metadata is trusted before an
    /// open re-probes the inner backend (`coherence_grace_ms`). Within the
    /// grace, cross-node coherence relies on the invalidation broadcast;
    /// past it, versioned keys are the correctness backstop.
    coherence_grace: Duration,
}

impl CachedBackend {
    pub fn new(
        inner: Arc<dyn Backend>,
        cache: Arc<ChunkCache>,
        readahead_chunks: usize,
        coherence_grace: Duration,
    ) -> CachedBackend {
        CachedBackend { inner, cache, readahead_chunks, coherence_grace }
    }

    fn source(&self, bucket: &str, obj: &str, base: u64, obj_len: u64, version: u64) -> CacheSource {
        CacheSource {
            inner: Arc::clone(&self.inner),
            cache: Arc::clone(&self.cache),
            bucket: bucket.to_string(),
            obj: obj.to_string(),
            base,
            obj_len,
            version,
            readahead_chunks: self.readahead_chunks,
            kind: FillKind::Demand,
        }
    }

    /// The object's (length, pinned version): remembered metadata within
    /// the coherence grace (no inner round trip — a fully cached object
    /// stays readable even if the inner backend is unreachable), re-probed
    /// past it. A definitive `NotFound` from the probe invalidates and
    /// propagates (delete visibility); an endpoint fault degrades to the
    /// remembered metadata of any age, because stale-but-available beats
    /// unavailable when the backstop cannot run anyway.
    fn object_meta(&self, bucket: &str, obj: &str) -> Result<(u64, u64, Option<u32>), StoreError> {
        if let Some(hit) = self.cache.remembered(bucket, obj, self.coherence_grace) {
            return Ok(hit);
        }
        match self.inner.stat(bucket, obj) {
            Ok(ObjectStat { len, version, crc }) => {
                let version = version.unwrap_or(0);
                self.cache.observe(bucket, obj, len, version, crc);
                Ok((len, version, crc))
            }
            Err(StoreError::NotFound(k)) => {
                self.cache.invalidate_object(bucket, obj);
                Err(StoreError::NotFound(k))
            }
            Err(e) => match self.cache.remembered_any(bucket, obj) {
                Some(hit) => Ok(hit),
                None => Err(e),
            },
        }
    }
}

impl Backend for CachedBackend {
    fn open_entry(&self, bucket: &str, obj: &str) -> Result<EntryReader, StoreError> {
        let (len, ver, _) = self.object_meta(bucket, obj)?;
        Ok(EntryReader::from_source(Box::new(self.source(bucket, obj, 0, len, ver)), len))
    }

    fn open_entry_range(
        &self,
        bucket: &str,
        obj: &str,
        offset: u64,
        len: u64,
    ) -> Result<EntryReader, StoreError> {
        let (total, ver, _) = self.object_meta(bucket, obj)?;
        if offset.saturating_add(len) > total {
            return Err(StoreError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("range {offset}+{len} past EOF ({total}) in {bucket}/{obj}"),
            )));
        }
        Ok(EntryReader::from_source(Box::new(self.source(bucket, obj, offset, total, ver)), len))
    }

    fn put(&self, bucket: &str, obj: &str, data: &[u8]) -> Result<(), StoreError> {
        let r = self.inner.put(bucket, obj, data);
        self.cache.invalidate_object(bucket, obj);
        r
    }

    fn exists(&self, bucket: &str, obj: &str) -> bool {
        self.inner.exists(bucket, obj)
    }

    fn size(&self, bucket: &str, obj: &str) -> Result<u64, StoreError> {
        self.inner.size(bucket, obj)
    }

    fn delete(&self, bucket: &str, obj: &str) -> Result<(), StoreError> {
        let r = self.inner.delete(bucket, obj);
        self.cache.invalidate_object(bucket, obj);
        r
    }

    fn list(&self, bucket: &str) -> Result<Vec<String>, StoreError> {
        self.inner.list(bucket)
    }

    fn content_crc(&self, bucket: &str, obj: &str) -> Option<u32> {
        self.inner.content_crc(bucket, obj)
    }

    /// The version this tier's *reads* are pinned to — remembered metadata
    /// within the grace, re-probed past it — NOT the inner tier's freshest
    /// version. Anything stacked on top (another cache, a remote consumer
    /// of the HTTP handler fronting this stack) gates its fills on the
    /// version of the bytes actually served; passing through a fresher
    /// inner version while still serving remembered-grace bytes would let
    /// an outer cache insert old bytes under a new pin.
    fn content_version(&self, bucket: &str, obj: &str) -> Option<u64> {
        match self.object_meta(bucket, obj) {
            Ok((_, 0, _)) => None,
            Ok((_, v, _)) => Some(v),
            Err(_) => None,
        }
    }

    /// Same pinned-metadata rule as [`Backend::content_version`] (see
    /// there): length, version AND crc come from `object_meta` — one probe
    /// answers the whole stat, and it is consistent with what a read
    /// through this tier returns.
    fn stat(&self, bucket: &str, obj: &str) -> Result<ObjectStat, StoreError> {
        let (len, version, crc) = self.object_meta(bucket, obj)?;
        Ok(ObjectStat {
            len,
            version: if version == 0 { None } else { Some(version) },
            crc,
        })
    }

    /// Warm every not-yet-cached chunk of the object, pinned for the
    /// demand read the epoch planner predicted. Fills run through the same
    /// read-ahead spans and version gate as demand fills — a prefetch
    /// racing an overwrite fails (or is invalidated later by `observe`)
    /// rather than planting stale bytes. Residency stays ≤ the cache
    /// capacity unconditionally: a prefetch insert never evicts pinned
    /// chunks and drops its own chunk when only pinned chunks remain.
    /// Transient fill residency is one span, bounded the same way as the
    /// demand path's (never against `dt_buffer_bytes`).
    fn prefetch(&self, bucket: &str, obj: &str) -> Result<u64, StoreError> {
        if self.cache.capacity() == 0 {
            return Ok(0);
        }
        let (len, ver, _) = self.object_meta(bucket, obj)?;
        if len == 0 {
            return Ok(0);
        }
        let mut src = self.source(bucket, obj, 0, len, ver);
        src.kind = FillKind::Prefetch;
        let cb = self.cache.chunk_bytes() as u64;
        let last_idx = (len - 1) / cb;
        let span = self.readahead_chunks as u64 + 1;
        let mut admitted = 0u64;
        let mut idx = 0u64;
        while idx <= last_idx {
            if self.cache.contains(bucket, obj, ver, idx) {
                idx += 1;
                continue;
            }
            let (_, n) = src.fill(idx)?;
            admitted += n;
            if n == 0 {
                // The cache declined the whole span (everything resident
                // is pinned): further spans would be declined too.
                break;
            }
            idx += span;
        }
        Ok(admitted)
    }
}

/// Source serving entry bytes from object-aligned cached chunks,
/// read-through to the inner backend on a miss. The whole source is pinned
/// to the object version observed at open: cached chunks are looked up
/// under that version, and fills refuse to complete if the inner version
/// moved — a read yields bytes of exactly one version or fails.
struct CacheSource {
    inner: Arc<dyn Backend>,
    cache: Arc<ChunkCache>,
    bucket: String,
    obj: String,
    /// Entry span start within the object (0 for whole objects).
    base: u64,
    /// Full object length (chunk alignment is object-relative so shard
    /// members share chunks).
    obj_len: u64,
    /// Pinned object version (0 = unversioned: no fill check possible).
    version: u64,
    readahead_chunks: usize,
    /// Demand (read-miss) or prefetch fills: decides insert pinning,
    /// eviction rights, and which fill counter the chunks land in.
    kind: FillKind,
}

impl CacheSource {
    /// Read-through fill on a miss: one sequential inner read covering the
    /// missing chunk plus up to `readahead_chunks` successors. The span is
    /// buffered before insertion so the version re-check below gates both
    /// serving *and* caching — transient residency is one fill span
    /// (≤ `(readahead_chunks + 1) × chunk_bytes`, clamped at boot to fit
    /// `dt_buffer_bytes`).
    ///
    /// Fills over a *hedged* remote inner tier need no extra handling
    /// here: a hedge (or failover) can change which endpoint serves the
    /// fill's bytes mid-span, but the remote source version-pins its own
    /// re-opens (fail-closed on a stamp change once bytes flowed) and
    /// surfaces the stamp via `observed_version` — which the gate below
    /// checks against this source's pin before any byte is served or
    /// cached.
    /// Returns the first chunk of the span plus how many chunks the cache
    /// actually admitted (a pin-aware prefetch insert may decline).
    fn fill(&self, idx: u64) -> Result<(Arc<Vec<u8>>, u64), StoreError> {
        let cb = self.cache.chunk_bytes() as u64;
        let last_idx = if self.obj_len == 0 { 0 } else { (self.obj_len - 1) / cb };
        let end_idx = idx.saturating_add(self.readahead_chunks as u64).min(last_idx);
        let start = idx * cb;
        let span = (self.obj_len.min((end_idx + 1) * cb)) - start;
        let mut reader = self.inner.open_entry_range(&self.bucket, &self.obj, start, span)?;
        let mut pieces: Vec<Arc<Vec<u8>>> = Vec::with_capacity((end_idx - idx + 1) as usize);
        for _ in idx..=end_idx {
            pieces.push(Arc::new(reader.read_chunk(cb as usize)?));
        }
        // Coherence gate: the bytes above can never be *newer* than the
        // version the fill's own reader observed (remote tier: the
        // `x-getbatch-version` stamp of the responses that carried the
        // bytes; local tier: the generation read after the file handle was
        // opened) — and, with no observation to go on, never newer than
        // what a version lookup now reports (local-tier invariant; over a
        // remote set both shapes additionally assume every endpoint fronts
        // the same store — the tier's standing contract, see
        // `store::remote`: with *divergent* replicas this gate, like every
        // ranged path, cannot protect). If that version equals the pin, the
        // bytes are exactly the pinned version. Anything else — superseded,
        // deleted, or unconfirmable because the probe itself failed — fails
        // the read: serving or caching unconfirmed bytes could mix versions
        // (soft error upstream; a retry re-opens at the current version).
        // Preferring the reader's observation keeps a remote cold fill at
        // one round trip per fill span — the separate 1-byte re-probe runs
        // only for inner tiers that don't surface versions on reads.
        if self.version != 0 {
            let confirmed = reader
                .observed_version()
                .or_else(|| self.inner.content_version(&self.bucket, &self.obj));
            match confirmed {
                Some(now) if now == self.version => {}
                Some(now) => {
                    return Err(StoreError::Io(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!(
                            "{}/{} overwritten under a pinned read (version {} superseded by {now})",
                            self.bucket, self.obj, self.version
                        ),
                    )));
                }
                None => {
                    return Err(StoreError::Io(io::Error::new(
                        io::ErrorKind::Other,
                        format!(
                            "{}/{}: pinned version {} could not be reconfirmed after a fill \
                             (object deleted, or the version probe failed)",
                            self.bucket, self.obj, self.version
                        ),
                    )));
                }
            }
        }
        let mut admitted = 0u64;
        for (k, piece) in pieces.iter().enumerate() {
            if self.cache.insert(
                &self.bucket,
                &self.obj,
                self.version,
                idx + k as u64,
                Arc::clone(piece),
                self.kind,
            ) {
                admitted += 1;
            }
        }
        Ok((Arc::clone(&pieces[0]), admitted))
    }
}

impl ChunkSource for CacheSource {
    /// The pin itself: every byte this source serves — cached chunk or
    /// gated fill — is exactly the pinned version, so a consumer stacked on
    /// top (another cache tier, the HTTP object handler stamping
    /// `x-getbatch-version` on ranged responses) can gate on it directly.
    fn observed_version(&self) -> Option<u64> {
        if self.version == 0 {
            None
        } else {
            Some(self.version)
        }
    }

    fn read_at(&mut self, pos: u64, buf: &mut [u8]) -> io::Result<usize> {
        let off = self.base + pos;
        if off >= self.obj_len || buf.is_empty() {
            return Ok(0);
        }
        let cb = self.cache.chunk_bytes() as u64;
        let idx = off / cb;
        let chunk = match self.cache.get(&self.bucket, &self.obj, self.version, idx) {
            Some(c) => c,
            None => self.fill(idx).map_err(io::Error::from)?.0,
        };
        let within = (off - idx * cb) as usize;
        if within >= chunk.len() {
            return Ok(0); // object shrank under the cache: reader surfaces EOF
        }
        let n = buf.len().min(chunk.len() - within);
        buf[..n].copy_from_slice(&chunk[within..within + n]);
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::local::LocalBackend;
    use std::path::PathBuf;

    /// Long grace: the classic cache tests exercise LRU/read-ahead, not
    /// revalidation.
    const LAZY: Duration = Duration::from_secs(3600);

    fn setup_grace(
        name: &str,
        cache_bytes: u64,
        chunk: usize,
        ra: usize,
        grace: Duration,
    ) -> (CachedBackend, Arc<ChunkCache>, Arc<LocalBackend>, PathBuf) {
        let base = std::env::temp_dir().join(format!("gbcache-{}-{}", std::process::id(), name));
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&base).unwrap();
        let local = Arc::new(LocalBackend::open(&base, 2).unwrap());
        let cache = Arc::new(ChunkCache::new(cache_bytes, chunk, None));
        let cached = CachedBackend::new(
            Arc::clone(&local) as Arc<dyn Backend>,
            Arc::clone(&cache),
            ra,
            grace,
        );
        (cached, cache, local, base)
    }

    fn setup(name: &str, cache_bytes: u64, chunk: usize, ra: usize) -> (CachedBackend, Arc<ChunkCache>, Arc<LocalBackend>, PathBuf) {
        setup_grace(name, cache_bytes, chunk, ra, LAZY)
    }

    fn payload(n: usize, seed: u32) -> Vec<u8> {
        (0..n as u32).map(|i| ((i.wrapping_mul(31).wrapping_add(seed)) % 251) as u8).collect()
    }

    #[test]
    fn cold_miss_then_warm_hit_byte_identical() {
        let (cached, cache, _local, base) = setup("warm", 1 << 20, 4 << 10, 0);
        let data = payload(50_000, 7);
        cached.put("b", "o", &data).unwrap();
        let cold = cached.open_entry("b", "o").unwrap().read_all().unwrap();
        assert_eq!(cold, data);
        let cold_misses = cache.misses.get();
        assert!(cold_misses > 0);
        let warm = cached.open_entry("b", "o").unwrap().read_all().unwrap();
        assert_eq!(warm, data);
        assert_eq!(cache.misses.get(), cold_misses, "warm read misses nothing");
        assert!(cache.hits.get() >= cold_misses, "every chunk re-served from cache");
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn lru_evicts_under_byte_pressure() {
        // 4 KiB chunks, 16 KiB cache → 4 resident chunks. Reading 10
        // distinct 4 KiB objects must evict, stay ≤ capacity, and still
        // serve every object byte-identically.
        let (cached, cache, _local, base) = setup("lru", 16 << 10, 4 << 10, 0);
        for i in 0..10 {
            cached.put("b", &format!("o{i}"), &payload(4 << 10, i)).unwrap();
        }
        for i in 0..10 {
            let got = cached.open_entry("b", &format!("o{i}")).unwrap().read_all().unwrap();
            assert_eq!(got, payload(4 << 10, i), "o{i} byte-identical through the cache");
        }
        assert!(cache.resident_bytes() <= cache.capacity());
        assert!(cache.evictions.get() >= 6, "evictions: {}", cache.evictions.get());
        // LRU order: the most recently read object is still resident.
        let before = cache.misses.get();
        let _ = cached.open_entry("b", "o9").unwrap().read_all().unwrap();
        assert_eq!(cache.misses.get(), before, "hottest object still cached");
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn readahead_prefetches_sequential_chunks() {
        // 8 chunks of 4 KiB; readahead 3 → the first touch fills chunks
        // 0..=3 in one inner read; touching chunk 1 next is a pure hit.
        let (cached, cache, _local, base) = setup("ra", 1 << 20, 4 << 10, 3);
        let data = payload(32 << 10, 3);
        cached.put("b", "o", &data).unwrap();
        let mut r = cached.open_entry("b", "o").unwrap();
        let first = r.read_chunk(4 << 10).unwrap();
        assert_eq!(first, &data[..4 << 10]);
        assert_eq!(cache.misses.get(), 1, "single miss triggers the fill");
        assert_eq!(cache.resident_bytes(), 4 * (4 << 10), "3 chunks prefetched");
        let second = r.read_chunk(4 << 10).unwrap();
        assert_eq!(second, &data[4 << 10..8 << 10]);
        assert_eq!(cache.misses.get(), 1, "read-ahead made chunk 1 a hit");
        assert!(cache.hits.get() >= 1);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn warm_object_readable_without_inner_backend() {
        let (cached, _cache, local, base) = setup("warmlen", 1 << 20, 4 << 10, 1);
        let data = payload(12 << 10, 4);
        cached.put("b", "o", &data).unwrap();
        assert_eq!(cached.open_entry("b", "o").unwrap().read_all().unwrap(), data);
        // Remove the object behind the cache's back: within the coherence
        // grace a fully warm object must still open (remembered metadata)
        // and serve every byte from cached chunks, with zero inner round
        // trips.
        local.delete("b", "o").unwrap();
        assert_eq!(cached.open_entry("b", "o").unwrap().read_all().unwrap(), data);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn put_invalidates_cached_chunks() {
        let (cached, cache, _local, base) = setup("inval", 1 << 20, 4 << 10, 1);
        cached.put("b", "o", &payload(12 << 10, 1)).unwrap();
        let _ = cached.open_entry("b", "o").unwrap().read_all().unwrap();
        assert!(cache.resident_bytes() > 0);
        let fresh = payload(12 << 10, 2);
        cached.put("b", "o", &fresh).unwrap();
        assert_eq!(cache.resident_bytes(), 0, "overwrite dropped stale chunks");
        assert!(cache.stale_evictions.get() > 0, "dropped chunks counted as stale");
        assert!(cache.invalidations.get() >= 2, "each PUT is an invalidation event");
        assert_eq!(cached.open_entry("b", "o").unwrap().read_all().unwrap(), fresh);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn ranged_members_share_object_chunks() {
        // Two spans of the same object: the second lands on chunks the
        // first already cached (object-aligned keys).
        let (cached, cache, _local, base) = setup("spans", 1 << 20, 4 << 10, 0);
        let data = payload(16 << 10, 9);
        cached.put("b", "o", &data).unwrap();
        let a = cached.open_entry_range("b", "o", 0, 8 << 10).unwrap().read_all().unwrap();
        assert_eq!(a, &data[..8 << 10]);
        let miss_after_a = cache.misses.get();
        let b = cached.open_entry_range("b", "o", 1024, 4096).unwrap().read_all().unwrap();
        assert_eq!(b, &data[1024..1024 + 4096]);
        assert_eq!(cache.misses.get(), miss_after_a, "overlapping span fully cached");
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn zero_length_objects_pass_through() {
        let (cached, _cache, _local, base) = setup("zero", 1 << 20, 4 << 10, 2);
        cached.put("b", "empty", b"").unwrap();
        let r = cached.open_entry("b", "empty").unwrap();
        assert!(r.is_empty());
        assert_eq!(r.read_all().unwrap(), b"");
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn zero_grace_revalidation_sees_out_of_band_overwrite() {
        // Grace 0: every open re-probes the inner backend. An overwrite
        // that bypassed this stack entirely (direct local put — the
        // "missed broadcast" shape) must be visible on the very next open,
        // with the stale chunks evicted under the stale counter.
        let (cached, cache, local, base) = setup_grace("reval", 1 << 20, 4 << 10, 1, Duration::ZERO);
        let v1 = payload(12 << 10, 1);
        local.put("b", "o", &v1).unwrap();
        assert_eq!(cached.open_entry("b", "o").unwrap().read_all().unwrap(), v1);
        assert!(cache.resident_bytes() > 0);
        let v2 = payload(12 << 10, 2);
        local.put("b", "o", &v2).unwrap(); // behind the cache's back
        assert_eq!(
            cached.open_entry("b", "o").unwrap().read_all().unwrap(),
            v2,
            "versioned keys make the stale chunks unreachable"
        );
        assert!(cache.stale_evictions.get() > 0, "old-version chunks evicted eagerly");
        // And the new version is warm now.
        let miss_before = cache.misses.get();
        assert_eq!(cached.open_entry("b", "o").unwrap().read_all().unwrap(), v2);
        assert_eq!(cache.misses.get(), miss_before, "new version cached");
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn zero_grace_sees_out_of_band_delete() {
        let (cached, cache, local, base) = setup_grace("delv", 1 << 20, 4 << 10, 0, Duration::ZERO);
        local.put("b", "o", &payload(8 << 10, 3)).unwrap();
        let _ = cached.open_entry("b", "o").unwrap().read_all().unwrap();
        local.delete("b", "o").unwrap();
        assert!(
            matches!(cached.open_entry("b", "o"), Err(StoreError::NotFound(_))),
            "delete visible at the next revalidating open"
        );
        assert_eq!(cache.resident_bytes(), 0, "deleted object's chunks dropped");
        std::fs::remove_dir_all(base).unwrap();
    }

    /// Counts explicit version probes reaching the inner tier — the fill
    /// gate must not issue any when the fill's own reader observed a
    /// version (over a remote inner each such probe is a wire round trip).
    struct ProbeCountingBackend {
        inner: Arc<LocalBackend>,
        version_probes: std::sync::atomic::AtomicU64,
    }

    impl Backend for ProbeCountingBackend {
        fn open_entry(&self, b: &str, o: &str) -> Result<EntryReader, StoreError> {
            self.inner.open_entry(b, o)
        }
        fn open_entry_range(
            &self,
            b: &str,
            o: &str,
            off: u64,
            len: u64,
        ) -> Result<EntryReader, StoreError> {
            self.inner.open_entry_range(b, o, off, len)
        }
        fn put(&self, b: &str, o: &str, d: &[u8]) -> Result<(), StoreError> {
            self.inner.put(b, o, d)
        }
        fn exists(&self, b: &str, o: &str) -> bool {
            self.inner.exists(b, o)
        }
        fn size(&self, b: &str, o: &str) -> Result<u64, StoreError> {
            self.inner.size(b, o)
        }
        fn delete(&self, b: &str, o: &str) -> Result<(), StoreError> {
            self.inner.delete(b, o)
        }
        fn list(&self, b: &str) -> Result<Vec<String>, StoreError> {
            self.inner.list(b)
        }
        fn content_crc(&self, b: &str, o: &str) -> Option<u32> {
            self.inner.content_crc(b, o)
        }
        fn content_version(&self, b: &str, o: &str) -> Option<u64> {
            self.version_probes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.inner.content_version(b, o)
        }
        fn stat(&self, b: &str, o: &str) -> Result<ObjectStat, StoreError> {
            self.inner.stat(b, o)
        }
    }

    #[test]
    fn fill_gate_reuses_readers_observed_version_without_extra_probe() {
        let base =
            std::env::temp_dir().join(format!("gbcache-{}-obsver", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&base).unwrap();
        let local = Arc::new(LocalBackend::open(&base, 2).unwrap());
        let counting = Arc::new(ProbeCountingBackend {
            inner: Arc::clone(&local),
            version_probes: Default::default(),
        });
        let cache = Arc::new(ChunkCache::new(1 << 20, 4 << 10, None));
        let cached = CachedBackend::new(
            Arc::clone(&counting) as Arc<dyn Backend>,
            Arc::clone(&cache),
            0,
            LAZY,
        );
        let data = payload(12 << 10, 5);
        cached.put("b", "o", &data).unwrap();
        assert_eq!(cached.open_entry("b", "o").unwrap().read_all().unwrap(), data);
        assert_eq!(
            counting.version_probes.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "every fill was gated on the reader's own observed version"
        );
        // And the cached reader re-surfaces its pin, so a tier stacked on
        // top of *this* one gets the same single-round-trip gate.
        let r = cached.open_entry("b", "o").unwrap();
        assert_eq!(r.observed_version(), local.content_version("b", "o"));
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn prefetch_warms_chunks_demand_reads_all_hit() {
        let (cached, cache, _local, base) = setup("pfwarm", 1 << 20, 4 << 10, 1);
        let data = payload(16 << 10, 11); // 4 chunks
        cached.put("b", "o", &data).unwrap();
        let filled = cached.prefetch("b", "o").unwrap();
        assert_eq!(filled, 4, "every chunk warmed");
        assert_eq!(cache.fills_prefetch.get(), 4);
        assert_eq!(cache.fills_demand.get(), 0);
        assert_eq!(cache.resident_bytes(), 16 << 10);
        // Idempotent: a second prefetch finds everything resident.
        assert_eq!(cached.prefetch("b", "o").unwrap(), 0);
        assert_eq!(cache.fills_prefetch.get(), 4, "no refill of warm chunks");
        // The demand read is all hits, and consuming the pinned chunks
        // counts as prefetch hits (and unpins them).
        let miss_before = cache.misses.get();
        assert_eq!(cached.open_entry("b", "o").unwrap().read_all().unwrap(), data);
        assert_eq!(cache.misses.get(), miss_before, "prefetched epoch read misses nothing");
        assert_eq!(cache.prefetch_hits.get(), 4);
        assert_eq!(cache.prefetch_wasted.get(), 0);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn prefetch_never_exceeds_capacity_or_evicts_pinned() {
        // Cache of 3 chunks. Object A (2 chunks) prefetched and pinned;
        // prefetching object B (3 chunks) may use the one free slot but
        // must not evict A's pinned chunks or overshoot capacity.
        let (cached, cache, _local, base) = setup("pfcap", 12 << 10, 4 << 10, 0);
        cached.put("b", "a", &payload(8 << 10, 1)).unwrap();
        cached.put("b", "bb", &payload(12 << 10, 2)).unwrap();
        assert_eq!(cached.prefetch("b", "a").unwrap(), 2);
        let admitted = cached.prefetch("b", "bb").unwrap();
        assert!(admitted <= 1, "only the unpinned slot was available, got {admitted}");
        assert!(cache.resident_bytes() <= cache.capacity());
        assert!(cache.prefetch_wasted.get() >= 1, "declined speculative chunks counted");
        // A's pinned chunks survived: reading A misses nothing.
        let miss_before = cache.misses.get();
        assert_eq!(cached.open_entry("b", "a").unwrap().read_all().unwrap(), payload(8 << 10, 1));
        assert_eq!(cache.misses.get(), miss_before);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn demand_churn_spares_pinned_chunks() {
        // Capacity 3 chunks; A (2 chunks) prefetched+pinned, then a demand
        // read of B (3 chunks) churns through the single unpinned slot
        // without evicting A.
        let (cached, cache, _local, base) = setup("pfpin", 12 << 10, 4 << 10, 0);
        cached.put("b", "a", &payload(8 << 10, 3)).unwrap();
        cached.put("b", "bb", &payload(12 << 10, 4)).unwrap();
        assert_eq!(cached.prefetch("b", "a").unwrap(), 2);
        assert_eq!(cached.open_entry("b", "bb").unwrap().read_all().unwrap(), payload(12 << 10, 4));
        assert!(cache.resident_bytes() <= cache.capacity());
        let miss_before = cache.misses.get();
        assert_eq!(cached.open_entry("b", "a").unwrap().read_all().unwrap(), payload(8 << 10, 3));
        assert_eq!(cache.misses.get(), miss_before, "pinned chunks outlived the demand churn");
        assert_eq!(cache.prefetch_hits.get(), 2);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn overwrite_invalidates_prefetched_chunks_as_wasted() {
        let (cached, cache, _local, base) = setup("pfinval", 1 << 20, 4 << 10, 0);
        cached.put("b", "o", &payload(8 << 10, 5)).unwrap();
        assert_eq!(cached.prefetch("b", "o").unwrap(), 2);
        let fresh = payload(8 << 10, 6);
        cached.put("b", "o", &fresh).unwrap(); // write-through invalidation
        assert_eq!(cache.prefetch_wasted.get(), 2, "unconsumed prefetched chunks dropped");
        assert_eq!(
            cached.open_entry("b", "o").unwrap().read_all().unwrap(),
            fresh,
            "post-overwrite read serves the fresh bytes, never the prefetched ones"
        );
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn fill_refuses_to_mix_versions_mid_read() {
        // Open a reader pinned at v1, let it consume the cached first
        // chunk, overwrite to v2, then force a fill for the second chunk:
        // the fill must fail (version superseded) rather than splice v2
        // bytes into a v1 read — and must not poison the cache.
        let (cached, cache, local, base) = setup_grace("pin", 1 << 20, 4 << 10, 0, LAZY);
        let v1 = payload(8 << 10, 1);
        local.put("b", "o", &v1).unwrap();
        // Warm only chunk 0 (ranged read), keeping chunk 1 cold.
        let got = cached.open_entry_range("b", "o", 0, 4 << 10).unwrap().read_all().unwrap();
        assert_eq!(got, &v1[..4 << 10]);
        let mut pinned = cached.open_entry("b", "o").unwrap();
        let head = pinned.read_chunk(4 << 10).unwrap();
        assert_eq!(head, &v1[..4 << 10], "head served from cache at v1");
        local.put("b", "o", &payload(8 << 10, 2)).unwrap(); // v2 out of band
        let tail = pinned.read_chunk(4 << 10);
        assert!(tail.is_err(), "fill across versions must fail, got {:?}", tail.map(|t| t.len()));
        // Nothing of v2 was inserted under the v1 key: a fresh open (which
        // revalidates nothing here — long grace, stale lens) still serves
        // the remembered v1 metadata but has no poisoned chunk 1.
        let hits_before = cache.hits.get();
        let _ = cached.open_entry_range("b", "o", 0, 4 << 10).unwrap().read_all().unwrap();
        assert!(cache.hits.get() > hits_before, "true v1 chunk 0 still cached");
        std::fs::remove_dir_all(base).unwrap();
    }
}
