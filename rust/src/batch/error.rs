//! Error taxonomy (§2.4.2): *hard* errors abort the request; *soft* errors
//! (missing objects/members, transient stream failures, sender timeouts) may
//! be tolerated under continue-on-error, surfacing as placeholders instead.

/// Why an individual entry failed.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum EntryError {
    #[error("object not found: {0}")]
    NotFound(String),
    #[error("archive member not found: {0}")]
    MemberNotFound(String),
    #[error("transient stream failure: {0}")]
    StreamFailure(String),
    #[error("timed out waiting for sender (entry {0})")]
    SenderTimeout(u32),
    #[error("local read failed: {0}")]
    ReadFailure(String),
}

impl EntryError {
    /// All per-entry retrieval errors are classified soft; only exhausted
    /// budgets (checked by the DT) escalate them to fatal (§2.4.2).
    pub fn is_soft(&self) -> bool {
        true
    }

    /// Whether get-from-neighbor recovery could plausibly resolve it.
    /// Missing data won't appear elsewhere under unique placement, but
    /// transient stream/read failures and timeouts are worth retrying.
    pub fn recoverable(&self) -> bool {
        matches!(
            self,
            EntryError::StreamFailure(_) | EntryError::SenderTimeout(_) | EntryError::ReadFailure(_)
        )
    }
}

/// Request-level failure.
#[derive(Debug, thiserror::Error)]
pub enum BatchError {
    #[error("request aborted: entry {index} failed: {source}")]
    EntryFailed {
        index: u32,
        #[source]
        source: EntryError,
    },
    #[error("soft-error budget exceeded ({count} > {limit})")]
    SoftErrorBudget { count: u32, limit: u32 },
    #[error("admission rejected: {0}")]
    Admission(String),
    #[error("bad request: {0}")]
    BadRequest(String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_classification() {
        assert!(EntryError::NotFound("x".into()).is_soft());
        assert!(EntryError::SenderTimeout(3).is_soft());
    }

    #[test]
    fn recoverability() {
        assert!(!EntryError::NotFound("x".into()).recoverable());
        assert!(!EntryError::MemberNotFound("x".into()).recoverable());
        assert!(EntryError::StreamFailure("rst".into()).recoverable());
        assert!(EntryError::SenderTimeout(0).recoverable());
        assert!(EntryError::ReadFailure("eio".into()).recoverable());
    }

    #[test]
    fn display_strings() {
        let e = BatchError::EntryFailed { index: 4, source: EntryError::NotFound("b/o".into()) };
        assert!(e.to_string().contains("entry 4"));
        let b = BatchError::SoftErrorBudget { count: 11, limit: 10 };
        assert!(b.to_string().contains("11 > 10"));
    }
}
