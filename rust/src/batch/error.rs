//! Error taxonomy (§2.4.2): *hard* errors abort the request; *soft* errors
//! (missing objects/members, transient stream failures, sender timeouts) may
//! be tolerated under continue-on-error, surfacing as placeholders instead.

use std::fmt;

/// Why an individual entry failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EntryError {
    NotFound(String),
    MemberNotFound(String),
    StreamFailure(String),
    SenderTimeout(u32),
    ReadFailure(String),
}

impl fmt::Display for EntryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EntryError::NotFound(k) => write!(f, "object not found: {k}"),
            EntryError::MemberNotFound(k) => write!(f, "archive member not found: {k}"),
            EntryError::StreamFailure(r) => write!(f, "transient stream failure: {r}"),
            EntryError::SenderTimeout(i) => write!(f, "timed out waiting for sender (entry {i})"),
            EntryError::ReadFailure(r) => write!(f, "local read failed: {r}"),
        }
    }
}

impl std::error::Error for EntryError {}

impl EntryError {
    /// All per-entry retrieval errors are classified soft; only exhausted
    /// budgets (checked by the DT) escalate them to fatal (§2.4.2).
    pub fn is_soft(&self) -> bool {
        true
    }

    /// Whether get-from-neighbor recovery could plausibly resolve it.
    /// Missing data won't appear elsewhere under unique placement, but
    /// transient stream/read failures and timeouts are worth retrying.
    pub fn recoverable(&self) -> bool {
        matches!(
            self,
            EntryError::StreamFailure(_) | EntryError::SenderTimeout(_) | EntryError::ReadFailure(_)
        )
    }
}

/// Request-level failure.
#[derive(Debug)]
pub enum BatchError {
    EntryFailed { index: u32, source: EntryError },
    SoftErrorBudget { count: u32, limit: u32 },
    Admission(String),
    BadRequest(String),
    Io(std::io::Error),
}

impl fmt::Display for BatchError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BatchError::EntryFailed { index, source } => {
                write!(f, "request aborted: entry {index} failed: {source}")
            }
            BatchError::SoftErrorBudget { count, limit } => {
                write!(f, "soft-error budget exceeded ({count} > {limit})")
            }
            BatchError::Admission(r) => write!(f, "admission rejected: {r}"),
            BatchError::BadRequest(r) => write!(f, "bad request: {r}"),
            BatchError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for BatchError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            BatchError::EntryFailed { source, .. } => Some(source),
            BatchError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for BatchError {
    fn from(e: std::io::Error) -> BatchError {
        BatchError::Io(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_classification() {
        assert!(EntryError::NotFound("x".into()).is_soft());
        assert!(EntryError::SenderTimeout(3).is_soft());
    }

    #[test]
    fn recoverability() {
        assert!(!EntryError::NotFound("x".into()).recoverable());
        assert!(!EntryError::MemberNotFound("x".into()).recoverable());
        assert!(EntryError::StreamFailure("rst".into()).recoverable());
        assert!(EntryError::SenderTimeout(0).recoverable());
        assert!(EntryError::ReadFailure("eio".into()).recoverable());
    }

    #[test]
    fn display_strings() {
        let e = BatchError::EntryFailed { index: 4, source: EntryError::NotFound("b/o".into()) };
        assert!(e.to_string().contains("entry 4"));
        let b = BatchError::SoftErrorBudget { count: 11, limit: 10 };
        assert!(b.to_string().contains("11 > 10"));
    }
}
