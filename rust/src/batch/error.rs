//! Error taxonomy (§2.4.2): *hard* errors abort the request; *soft* errors
//! (missing objects/members, transient stream failures, sender timeouts) may
//! be tolerated under continue-on-error, surfacing as placeholders instead.

/// Why an individual entry failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EntryError {
    NotFound(String),
    MemberNotFound(String),
    StreamFailure(String),
    SenderTimeout(u32),
    ReadFailure(String),
}

crate::impl_error! {
    EntryError {
        display {
            EntryError::NotFound(k) => "object not found: {k}",
            EntryError::MemberNotFound(k) => "archive member not found: {k}",
            EntryError::StreamFailure(r) => "transient stream failure: {r}",
            EntryError::SenderTimeout(i) => "timed out waiting for sender (entry {i})",
            EntryError::ReadFailure(r) => "local read failed: {r}",
        }
    }
}

impl EntryError {
    /// All per-entry retrieval errors are classified soft; only exhausted
    /// budgets (checked by the DT) escalate them to fatal (§2.4.2).
    pub fn is_soft(&self) -> bool {
        true
    }

    /// Whether get-from-neighbor recovery could plausibly resolve it.
    /// Missing data won't appear elsewhere under unique placement, but
    /// transient stream/read failures and timeouts are worth retrying.
    pub fn recoverable(&self) -> bool {
        matches!(
            self,
            EntryError::StreamFailure(_) | EntryError::SenderTimeout(_) | EntryError::ReadFailure(_)
        )
    }
}

/// Request-level failure.
#[derive(Debug)]
pub enum BatchError {
    EntryFailed { index: u32, source: EntryError },
    SoftErrorBudget { count: u32, limit: u32 },
    Admission(String),
    BadRequest(String),
    Io(std::io::Error),
}

crate::impl_error! {
    BatchError {
        display {
            BatchError::EntryFailed { index, source } =>
                "request aborted: entry {index} failed: {source}",
            BatchError::SoftErrorBudget { count, limit } =>
                "soft-error budget exceeded ({count} > {limit})",
            BatchError::Admission(r) => "admission rejected: {r}",
            BatchError::BadRequest(r) => "bad request: {r}",
            BatchError::Io(e) => "io: {e}",
        }
        source {
            BatchError::EntryFailed { source, .. } => source,
            BatchError::Io(e) => e,
        }
        from {
            std::io::Error => Io,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_classification() {
        assert!(EntryError::NotFound("x".into()).is_soft());
        assert!(EntryError::SenderTimeout(3).is_soft());
    }

    #[test]
    fn recoverability() {
        assert!(!EntryError::NotFound("x".into()).recoverable());
        assert!(!EntryError::MemberNotFound("x".into()).recoverable());
        assert!(EntryError::StreamFailure("rst".into()).recoverable());
        assert!(EntryError::SenderTimeout(0).recoverable());
        assert!(EntryError::ReadFailure("eio".into()).recoverable());
    }

    #[test]
    fn display_strings() {
        let e = BatchError::EntryFailed { index: 4, source: EntryError::NotFound("b/o".into()) };
        assert!(e.to_string().contains("entry 4"));
        let b = BatchError::SoftErrorBudget { count: 11, limit: 10 };
        assert!(b.to_string().contains("11 > 10"));
    }
}
