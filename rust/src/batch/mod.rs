//! GetBatch request/response model: the entry list a client submits, the
//! execution options (§2.4.1), the ordered response reader, and the
//! hard/soft error taxonomy (§2.4.2).

pub mod request;
pub mod reader;
pub mod error;
