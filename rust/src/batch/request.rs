//! The GetBatch request: an ordered list of entries (objects or archive
//! members, possibly spanning buckets) plus execution options. Ships as the
//! JSON body of an HTTP GET (§2.2).

use crate::util::json::Value;

/// Output serialization format. The paper's default is uncompressed TAR;
/// TGZ is provided as the natural extension (shards on disk may be either).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OutputFormat {
    #[default]
    Tar,
    Tgz,
}

impl OutputFormat {
    pub fn as_str(&self) -> &'static str {
        match self {
            OutputFormat::Tar => "tar",
            OutputFormat::Tgz => "tgz",
        }
    }
    pub fn parse(s: &str) -> Option<OutputFormat> {
        match s {
            "tar" | ".tar" => Some(OutputFormat::Tar),
            "tgz" | ".tgz" | "tar.gz" => Some(OutputFormat::Tgz),
            _ => None,
        }
    }
}

/// One requested item: a standalone object, or — when `archpath` is set — a
/// member to extract from a TAR shard (§2.2 "standalone objects or archive
/// shards").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchEntry {
    pub bucket: String,
    pub obj: String,
    /// Member path within the shard `obj`, if extracting.
    pub archpath: Option<String>,
}

impl BatchEntry {
    pub fn obj(bucket: &str, obj: &str) -> BatchEntry {
        BatchEntry { bucket: bucket.to_string(), obj: obj.to_string(), archpath: None }
    }

    pub fn member(bucket: &str, shard: &str, member: &str) -> BatchEntry {
        BatchEntry {
            bucket: bucket.to_string(),
            obj: shard.to_string(),
            archpath: Some(member.to_string()),
        }
    }

    /// Placement key: shard members live wherever their shard object lives.
    pub fn location_key(&self) -> String {
        format!("{}/{}", self.bucket, self.obj)
    }

    /// Name of this entry in the output TAR stream. Members keep their
    /// in-archive path so downstream consumers see stable names.
    pub fn output_name(&self) -> String {
        match &self.archpath {
            Some(m) => format!("{}/{}", self.obj, m),
            None => self.obj.clone(),
        }
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj()
            .set("bucket", Value::str(&self.bucket))
            .set("objname", Value::str(&self.obj));
        if let Some(a) = &self.archpath {
            v = v.set("archpath", Value::str(a));
        }
        v
    }

    pub fn from_json(v: &Value) -> Option<BatchEntry> {
        Some(BatchEntry {
            bucket: v.str_field("bucket")?.to_string(),
            obj: v.str_field("objname")?.to_string(),
            archpath: v.str_field("archpath").map(|s| s.to_string()),
        })
    }
}

/// Execution options (§2.4.1). None of these change correctness — only how
/// the request executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchOpts {
    /// Streaming: DT starts emitting as soon as head-of-line entries are
    /// ready (vs. buffering the whole result).
    pub streaming: bool,
    /// Continue-on-error: soft failures become placeholder entries instead
    /// of aborting the request.
    pub continue_on_err: bool,
    /// Colocation hint: proxy unmarshals the entry list and picks the DT
    /// owning the largest fraction of requested data.
    pub colocation: bool,
    pub output: OutputFormat,
}

impl Default for BatchOpts {
    fn default() -> Self {
        BatchOpts {
            streaming: true,
            continue_on_err: false,
            colocation: false,
            output: OutputFormat::Tar,
        }
    }
}

/// A full GetBatch request.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct BatchRequest {
    pub entries: Vec<BatchEntry>,
    pub opts: BatchOpts,
}

impl BatchRequest {
    pub fn new(entries: Vec<BatchEntry>) -> BatchRequest {
        BatchRequest { entries, opts: BatchOpts::default() }
    }

    pub fn streaming(mut self, on: bool) -> Self {
        self.opts.streaming = on;
        self
    }
    pub fn continue_on_err(mut self, on: bool) -> Self {
        self.opts.continue_on_err = on;
        self
    }
    pub fn colocation(mut self, on: bool) -> Self {
        self.opts.colocation = on;
        self
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("output_format", Value::str(self.opts.output.as_str()))
            .set("streaming", Value::Bool(self.opts.streaming))
            .set("continue_on_err", Value::Bool(self.opts.continue_on_err))
            .set(
                "in",
                Value::Arr(self.entries.iter().map(|e| e.to_json()).collect()),
            )
    }

    pub fn to_body(&self) -> Vec<u8> {
        self.to_json().to_string().into_bytes()
    }

    pub fn from_json(v: &Value) -> Option<BatchRequest> {
        let entries = v
            .get("in")?
            .as_arr()?
            .iter()
            .map(BatchEntry::from_json)
            .collect::<Option<Vec<_>>>()?;
        let opts = BatchOpts {
            streaming: v.bool_field("streaming").unwrap_or(true),
            continue_on_err: v.bool_field("continue_on_err").unwrap_or(false),
            colocation: false, // rides the query string, not the body (§2.4.1)
            output: v
                .str_field("output_format")
                .and_then(OutputFormat::parse)
                .unwrap_or_default(),
        };
        Some(BatchRequest { entries, opts })
    }

    pub fn from_body(body: &[u8]) -> Option<BatchRequest> {
        let s = std::str::from_utf8(body).ok()?;
        BatchRequest::from_json(&Value::parse(s).ok()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_kinds() {
        let o = BatchEntry::obj("b1", "x.wav");
        assert_eq!(o.location_key(), "b1/x.wav");
        assert_eq!(o.output_name(), "x.wav");
        let m = BatchEntry::member("b1", "shard-0001.tar", "utt/17.wav");
        assert_eq!(m.location_key(), "b1/shard-0001.tar");
        assert_eq!(m.output_name(), "shard-0001.tar/utt/17.wav");
    }

    #[test]
    fn json_roundtrip() {
        let req = BatchRequest::new(vec![
            BatchEntry::obj("audio", "a.wav"),
            BatchEntry::member("audio", "s.tar", "m.wav"),
            BatchEntry::obj("labels", "a.txt"),
        ])
        .continue_on_err(true)
        .streaming(false);
        let body = req.to_body();
        let back = BatchRequest::from_body(&body).unwrap();
        assert_eq!(back.entries, req.entries);
        assert_eq!(back.opts.continue_on_err, true);
        assert_eq!(back.opts.streaming, false);
        assert_eq!(back.opts.output, OutputFormat::Tar);
    }

    #[test]
    fn multi_bucket_in_one_request() {
        let req = BatchRequest::new(vec![
            BatchEntry::obj("features", "f0"),
            BatchEntry::obj("labels", "l0"),
        ]);
        let back = BatchRequest::from_body(&req.to_body()).unwrap();
        assert_eq!(back.entries[0].bucket, "features");
        assert_eq!(back.entries[1].bucket, "labels");
    }

    #[test]
    fn malformed_body_rejected() {
        assert!(BatchRequest::from_body(b"not json").is_none());
        assert!(BatchRequest::from_body(b"{}").is_none());
        assert!(BatchRequest::from_body(br#"{"in":[{"bucket":"b"}]}"#).is_none());
    }

    #[test]
    fn defaults() {
        let o = BatchOpts::default();
        assert!(o.streaming);
        assert!(!o.continue_on_err);
        assert!(!o.colocation);
        assert_eq!(o.output, OutputFormat::Tar);
    }
}
