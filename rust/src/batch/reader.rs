//! Client-side ordered response reader: iterates the GetBatch TAR stream,
//! yielding entries in exact request order, with continue-on-error
//! placeholders surfaced as `BatchItem::Missing` (§2.2 ordering guarantee).

use std::io::Read;

use crate::tar::{self, TarReader};

/// One item of a batch response, in request order.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchItem {
    /// Successfully retrieved entry.
    Ok { name: String, data: Vec<u8> },
    /// Continue-on-error placeholder: the entry could not be retrieved.
    Missing { name: String },
}

impl BatchItem {
    pub fn name(&self) -> &str {
        match self {
            BatchItem::Ok { name, .. } => name,
            BatchItem::Missing { name } => name,
        }
    }
    pub fn data(&self) -> Option<&[u8]> {
        match self {
            BatchItem::Ok { data, .. } => Some(data),
            BatchItem::Missing { .. } => None,
        }
    }
    pub fn is_missing(&self) -> bool {
        matches!(self, BatchItem::Missing { .. })
    }
}

/// Streaming iterator over a GetBatch response body.
pub struct BatchReader<R: Read> {
    inner: TarReader<R>,
}

impl<R: Read> BatchReader<R> {
    pub fn new(body: R) -> BatchReader<R> {
        BatchReader { inner: TarReader::new(body) }
    }

    pub fn next_item(&mut self) -> Result<Option<BatchItem>, tar::TarError> {
        match self.inner.next_entry()? {
            None => Ok(None),
            Some(e) => {
                if let Some(orig) = tar::missing_original(&e.name) {
                    Ok(Some(BatchItem::Missing { name: orig.to_string() }))
                } else {
                    Ok(Some(BatchItem::Ok { name: e.name, data: e.data }))
                }
            }
        }
    }

    /// Drain the stream into a vector (small batches / tests).
    pub fn collect_all(mut self) -> Result<Vec<BatchItem>, tar::TarError> {
        let mut out = Vec::new();
        while let Some(item) = self.next_item()? {
            out.push(item);
        }
        Ok(out)
    }
}

impl<R: Read> Iterator for BatchReader<R> {
    type Item = Result<BatchItem, tar::TarError>;
    fn next(&mut self) -> Option<Self::Item> {
        self.next_item().transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tar::TarWriter;
    use std::io::Cursor;

    #[test]
    fn yields_in_order_with_placeholders() {
        let mut w = TarWriter::new(Vec::new());
        w.append("e0", b"aaa").unwrap();
        w.append_missing("e1").unwrap();
        w.append("e2", b"cc").unwrap();
        let bytes = w.into_inner().unwrap();

        let items = BatchReader::new(Cursor::new(bytes)).collect_all().unwrap();
        assert_eq!(items.len(), 3);
        assert_eq!(items[0], BatchItem::Ok { name: "e0".into(), data: b"aaa".to_vec() });
        assert_eq!(items[1], BatchItem::Missing { name: "e1".into() });
        assert!(items[1].is_missing());
        assert_eq!(items[2].data(), Some(&b"cc"[..]));
    }

    #[test]
    fn empty_stream() {
        let w = TarWriter::new(Vec::new());
        let bytes = w.into_inner().unwrap();
        let items = BatchReader::new(Cursor::new(bytes)).collect_all().unwrap();
        assert!(items.is_empty());
    }

    #[test]
    fn iterator_interface() {
        let mut w = TarWriter::new(Vec::new());
        for i in 0..5 {
            w.append(&format!("e{i}"), &[i as u8]).unwrap();
        }
        let bytes = w.into_inner().unwrap();
        let names: Vec<String> = BatchReader::new(Cursor::new(bytes))
            .map(|r| r.unwrap().name().to_string())
            .collect();
        assert_eq!(names, vec!["e0", "e1", "e2", "e3", "e4"]);
    }
}
