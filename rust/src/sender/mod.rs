//! The sender role (§2.3.1 phase 2): upon activation, a target
//! independently determines which request entries it owns — whole objects
//! or members of locally stored shards — reads them, and pushes the
//! payloads to the DT over the pooled P2P transport. Senders are
//! autonomous: no sender-to-sender coordination, delivery starts as soon as
//! local reads complete.

use std::sync::Arc;

use crate::batch::request::BatchEntry;
use crate::cluster::placement;
use crate::cluster::smap::Smap;
use crate::config::GetBatchConfig;
use crate::metrics::GetBatchMetrics;
use crate::proto::frame::{Frame, FrameHead, FrameType, FLAG_FIRST, FLAG_LAST, FLAG_WHOLE};
use crate::proto::wire::SenderActivate;
use crate::store::shard::ShardError;
use crate::store::{EntryReader, ObjectStore, ShardIndexCache, StoreError};
use crate::transport::PeerPool;

/// Resolve one entry from the local store as a streaming [`EntryReader`] —
/// whole object or a range-bounded shard member. Nothing is materialized
/// here; the caller pulls `chunk_bytes` pieces.
pub fn resolve_entry(
    store: &ObjectStore,
    shards: &ShardIndexCache,
    e: &BatchEntry,
) -> Result<EntryReader, String> {
    match &e.archpath {
        None => store.open_entry(&e.bucket, &e.obj).map_err(|err| match err {
            StoreError::NotFound(k) => format!("missing object {k}"),
            StoreError::Io(io) => format!("read failure: {io}"),
        }),
        Some(member) => shards.extract(store, &e.bucket, &e.obj, member).map_err(|err| match err {
            ShardError::MemberNotFound { shard, member } => {
                format!("missing member {shard}!{member}")
            }
            ShardError::Store(StoreError::NotFound(k)) => format!("missing object {k}"),
            other => format!("read failure: {other}"),
        }),
    }
}

/// The sender hot loop as a `PeerPool::send_stream` producer: one entry
/// open at a time, each chunk read straight off its [`EntryReader`] into
/// the *reused* payload buffer (`EntryReader::read_chunk_into`) — sender
/// residency is O(chunk) and the loop allocates no per-chunk `Vec`. A read
/// failure *after* the FIRST frame went out surfaces as a SOFT_ERR frame:
/// the DT fails the slot promptly and, if bytes were already consumed
/// there, repairs it via the ranged GFN splice.
struct SenderStream<'a> {
    req_id: u64,
    chunk_bytes: usize,
    mine: Vec<(u32, &'a BatchEntry)>,
    next_entry: usize,
    /// The entry currently being streamed.
    current: Option<(u32, EntryReader)>,
    satisfied: u32,
    done_sent: bool,
    store: &'a ObjectStore,
    shards: &'a ShardIndexCache,
    metrics: &'a GetBatchMetrics,
}

impl SenderStream<'_> {
    /// Produce the next frame into `payload`; `None` ends the burst (after
    /// SENDER_DONE went out).
    fn next_frame(&mut self, payload: &mut Vec<u8>) -> Option<FrameHead> {
        loop {
            if self.done_sent {
                return None;
            }
            if let Some((idx, reader)) = self.current.as_mut() {
                let idx = *idx;
                let total = reader.len();
                let first = reader.pos() == 0;
                let multi = total > self.chunk_bytes as u64;
                if first && multi {
                    // FIRST chunk of a multi-chunk entry carries the 8-byte
                    // total prefix ahead of the chunk bytes.
                    payload.extend_from_slice(&total.to_le_bytes());
                }
                match reader.read_chunk_into(payload, self.chunk_bytes) {
                    Ok(_) => {
                        let last = reader.remaining() == 0;
                        if last {
                            self.current = None;
                        }
                        self.metrics.sender_chunks.inc();
                        self.metrics.sender_peak_buffer.set_max(payload.len() as i64);
                        let flags = if !multi {
                            FLAG_WHOLE
                        } else if first {
                            FLAG_FIRST
                        } else if last {
                            FLAG_LAST
                        } else {
                            0
                        };
                        return Some(FrameHead {
                            ftype: FrameType::Data,
                            flags,
                            req_id: self.req_id,
                            index: idx,
                        });
                    }
                    Err(e) => {
                        self.current = None;
                        payload.clear();
                        payload.extend_from_slice(format!("read failure: {e}").as_bytes());
                        return Some(FrameHead {
                            ftype: FrameType::SoftErr,
                            flags: 0,
                            req_id: self.req_id,
                            index: idx,
                        });
                    }
                }
            }
            if self.next_entry >= self.mine.len() {
                // SENDER_DONE rides the same connection after the last data
                // frame, carrying the final satisfied count.
                self.done_sent = true;
                return Some(FrameHead {
                    ftype: FrameType::SenderDone,
                    flags: 0,
                    req_id: self.req_id,
                    index: self.satisfied,
                });
            }
            let (idx, e) = self.mine[self.next_entry];
            self.next_entry += 1;
            match resolve_entry(self.store, self.shards, e) {
                Ok(reader) => {
                    self.satisfied += 1;
                    self.metrics.sender_entries.inc();
                    self.current = Some((idx, reader));
                    // loop around to cut its first chunk
                }
                Err(reason) => {
                    payload.extend_from_slice(reason.as_bytes());
                    return Some(FrameHead {
                        ftype: FrameType::SoftErr,
                        flags: 0,
                        req_id: self.req_id,
                        index: idx,
                    });
                }
            }
        }
    }
}

/// Execute a sender activation: stream every locally-owned entry to the DT,
/// then emit SENDER_DONE. Runs on the target's background pool. Entries
/// stream lazily (`send_stream`) so transmission overlaps the next read;
/// entries larger than `cfg.chunk_bytes` are split into chunk frames read
/// straight off an [`EntryReader`], so the DT can emit them before their
/// last byte arrives, sender residency stays O(chunk) instead of O(object),
/// and DT-side memory backpressure (its budget stalling our socket) pauses
/// us between chunks *and between disk reads* instead of after whole
/// objects.
pub fn run_sender(
    act: &SenderActivate,
    smap: &Smap,
    self_target: usize,
    store: &Arc<ObjectStore>,
    shards: &ShardIndexCache,
    pool: &Arc<PeerPool>,
    metrics: &GetBatchMetrics,
    cfg: &GetBatchConfig,
    readahead: Option<&crate::util::threadpool::ThreadPool>,
) {
    let mine = placement::local_entries(smap, &act.request, self_target);
    if mine.is_empty() {
        // Still signal DONE so the DT's completion accounting balances.
        let _ = pool.send(&act.dt_peer, &[Frame::sender_done(act.req_id, 0)]);
        return;
    }

    // Read-ahead workers warm the page cache for upcoming local reads
    // (§2.4.3). Best-effort: errors surface on the real read below.
    if let Some(ra) = readahead {
        for (_, e) in mine.iter().skip(1).take(8) {
            let store = Arc::clone(store);
            let bucket = e.bucket.clone();
            let obj = e.obj.clone();
            ra.execute(move || {
                // Touch the head of the file; the OS pulls pages in.
                let _ = store.get_range(&bucket, &obj, 0, store.size(&bucket, &obj).unwrap_or(0).min(256 << 10));
            });
        }
    }

    // Fully lazy: each entry is opened as a streaming reader when its first
    // frame is cut, and each chunk is read from disk only when transmitted —
    // sender residency is O(chunk_bytes) regardless of entry size, and the
    // lending `send_stream` path reuses one payload buffer for every chunk
    // frame of the burst (no per-chunk allocation).
    let mut stream = SenderStream {
        req_id: act.req_id,
        chunk_bytes: cfg.chunk_bytes.max(1),
        mine,
        next_entry: 0,
        current: None,
        satisfied: 0,
        done_sent: false,
        store: store.as_ref(),
        shards,
        metrics,
    };
    if pool.send_stream(&act.dt_peer, |payload| stream.next_frame(payload)).is_err() {
        // P2P path down: the DT's sender-wait timeout + GFN recovery take
        // over; nothing else to do here.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::request::BatchRequest;
    use crate::cluster::smap::NodeInfo;
    use crate::tar::{write_archive, Entry};
    use std::path::PathBuf;
    use std::time::Duration;

    fn setup(name: &str) -> (Arc<ObjectStore>, ShardIndexCache, PathBuf) {
        let base = std::env::temp_dir().join(format!("gbsend-{}-{}", std::process::id(), name));
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&base).unwrap();
        (Arc::new(ObjectStore::open(&base, 2).unwrap()), ShardIndexCache::new(16), base)
    }

    #[test]
    fn resolve_object_and_member() {
        let (store, shards, base) = setup("resolve");
        store.put("b", "o", b"data").unwrap();
        let archive = write_archive(&[Entry { name: "m.wav".into(), data: vec![7; 10] }]).unwrap();
        store.put("b", "s.tar", &archive).unwrap();

        let r = resolve_entry(&store, &shards, &BatchEntry::obj("b", "o")).unwrap();
        assert_eq!(r.len(), 4, "length known before any byte is read");
        assert_eq!(r.read_all().unwrap(), b"data");
        assert_eq!(
            resolve_entry(&store, &shards, &BatchEntry::member("b", "s.tar", "m.wav"))
                .unwrap()
                .read_all()
                .unwrap(),
            vec![7; 10]
        );
        let e = resolve_entry(&store, &shards, &BatchEntry::obj("b", "nope")).unwrap_err();
        assert!(e.starts_with("missing object"), "{e}");
        let e =
            resolve_entry(&store, &shards, &BatchEntry::member("b", "s.tar", "zz")).unwrap_err();
        assert!(e.starts_with("missing member"), "{e}");
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn sender_streams_local_entries_and_done() {
        let (store, shards, base) = setup("stream");
        // single-target smap: this sender owns everything
        let smap = Smap::new(
            1,
            vec![],
            vec![NodeInfo { id: "t0".into(), http_addr: String::new(), p2p_addr: String::new() }],
        );
        for i in 0..5 {
            store.put("b", &format!("o{i}"), format!("payload-{i}").as_bytes()).unwrap();
        }
        store.put("b", "gone", b"x").unwrap();
        store.delete("b", "gone").unwrap();

        let (tx, rx) = std::sync::mpsc::channel();
        let tx = std::sync::Mutex::new(tx);
        let p2p = crate::transport::P2pServer::serve(
            Arc::new(move |f| {
                let _ = tx.lock().unwrap().send(f);
            }),
            "dt",
        )
        .unwrap();
        let pool = PeerPool::new(Duration::from_secs(5));
        let metrics = GetBatchMetrics::new();

        let mut entries: Vec<BatchEntry> =
            (0..5).map(|i| BatchEntry::obj("b", &format!("o{i}"))).collect();
        entries.push(BatchEntry::obj("b", "gone"));
        let act = SenderActivate {
            req_id: 11,
            dt_peer: p2p.addr.to_string(),
            request: BatchRequest::new(entries),
        };
        run_sender(&act, &smap, 0, &store, &shards, &pool, &metrics, &GetBatchConfig::default(), None);

        let mut data = 0;
        let mut soft = 0;
        let mut done = 0;
        for _ in 0..7 {
            let f = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert_eq!(f.req_id, 11);
            match f.ftype {
                crate::proto::frame::FrameType::Data => {
                    assert_eq!(
                        f.payload,
                        format!("payload-{}", f.index).as_bytes(),
                        "index/payload aligned"
                    );
                    data += 1;
                }
                crate::proto::frame::FrameType::SoftErr => {
                    assert_eq!(f.index, 5);
                    soft += 1;
                }
                crate::proto::frame::FrameType::SenderDone => {
                    assert_eq!(f.index, 5, "satisfied count");
                    done += 1;
                }
            }
        }
        assert_eq!((data, soft, done), (5, 1, 1));
        assert_eq!(metrics.sender_entries.get(), 5);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn large_objects_stream_as_chunks_and_reassemble() {
        let (store, shards, base) = setup("chunks");
        let smap = Smap::new(
            1,
            vec![],
            vec![NodeInfo { id: "t0".into(), http_addr: String::new(), p2p_addr: String::new() }],
        );
        let mut rng = crate::util::rng::Rng::new(77);
        let mut big = vec![0u8; 300 << 10]; // 300 KiB ≫ 32 KiB chunks
        rng.fill_bytes(&mut big);
        store.put("b", "big", &big).unwrap();
        store.put("b", "small", b"tiny").unwrap();

        // Receive through a real DT registry so the chunk path is exercised
        // end-to-end: sender → frames → dispatch → reorder buffer.
        let registry = crate::dt::exec::DtRegistry::new();
        let entries =
            vec![BatchEntry::obj("b", "big"), BatchEntry::obj("b", "small")];
        let request = BatchRequest::new(entries);
        let exec = registry.register(crate::dt::exec::DtExec::new(21, request.clone(), 1));
        let reg2 = Arc::clone(&registry);
        let p2p =
            crate::transport::P2pServer::serve(Arc::new(move |f| reg2.dispatch(f)), "dt").unwrap();
        let pool = PeerPool::new(Duration::from_secs(5));
        let metrics = GetBatchMetrics::new();
        let cfg = GetBatchConfig { chunk_bytes: 32 << 10, ..Default::default() };
        let act = SenderActivate { req_id: 21, dt_peer: p2p.addr.to_string(), request };
        run_sender(&act, &smap, 0, &store, &shards, &pool, &metrics, &cfg, None);

        match exec.buf.wait_take(0, Duration::from_secs(5)) {
            crate::dt::order::SlotWait::Ready(d) => assert_eq!(d, big),
            other => panic!("big: {other:?}"),
        }
        match exec.buf.wait_take(1, Duration::from_secs(5)) {
            crate::dt::order::SlotWait::Ready(d) => assert_eq!(d, b"tiny"),
            other => panic!("small: {other:?}"),
        }
        assert!(metrics.sender_chunks.get() >= 10, "big object split into ≥10 chunks");
        // Streaming reads: the sender never materialized more than ~one
        // chunk of the 300 KiB entry at a time.
        let peak = metrics.sender_peak_buffer.get();
        assert!(peak > 0, "peak buffer recorded");
        assert!(
            peak <= 2 * (32 << 10),
            "sender residency {peak} exceeded 2x chunk_bytes"
        );
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn sender_with_no_local_entries_sends_done_only() {
        let (store, shards, base) = setup("empty");
        // two targets; choose the one that owns nothing for this request
        let smap = Smap::new(
            1,
            vec![],
            (0..2)
                .map(|i| NodeInfo {
                    id: format!("t{i}"),
                    http_addr: String::new(),
                    p2p_addr: String::new(),
                })
                .collect(),
        );
        let req = BatchRequest::new(vec![BatchEntry::obj("b", "o1")]);
        let owner = placement::entry_owner(&smap, &req.entries[0]);
        let other = 1 - owner;

        let (tx, rx) = std::sync::mpsc::channel();
        let tx = std::sync::Mutex::new(tx);
        let p2p = crate::transport::P2pServer::serve(
            Arc::new(move |f| {
                let _ = tx.lock().unwrap().send(f);
            }),
            "dt",
        )
        .unwrap();
        let pool = PeerPool::new(Duration::from_secs(5));
        let metrics = GetBatchMetrics::new();
        let act = SenderActivate { req_id: 9, dt_peer: p2p.addr.to_string(), request: req };
        run_sender(&act, &smap, other, &store, &shards, &pool, &metrics, &GetBatchConfig::default(), None);
        let f = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(f.ftype, crate::proto::frame::FrameType::SenderDone);
        assert_eq!(f.index, 0);
        std::fs::remove_dir_all(base).unwrap();
    }
}
