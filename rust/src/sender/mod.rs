//! The sender role (§2.3.1 phase 2): upon activation, a target
//! independently determines which request entries it owns — whole objects
//! or members of locally stored shards — reads them, and pushes the
//! payloads to the DT over the pooled P2P transport. Senders are
//! autonomous: no sender-to-sender coordination, delivery starts as soon as
//! local reads complete.

use std::cell::Cell;
use std::sync::Arc;

use crate::batch::request::BatchEntry;
use crate::cluster::placement;
use crate::cluster::smap::Smap;
use crate::config::GetBatchConfig;
use crate::metrics::GetBatchMetrics;
use crate::proto::frame::{chunk_count, Frame};
use crate::proto::wire::SenderActivate;
use crate::store::shard::ShardError;
use crate::store::{EntryReader, ObjectStore, ShardIndexCache, StoreError};
use crate::transport::PeerPool;

/// Resolve one entry from the local store as a streaming [`EntryReader`] —
/// whole object or a range-bounded shard member. Nothing is materialized
/// here; the caller pulls `chunk_bytes` pieces.
pub fn resolve_entry(
    store: &ObjectStore,
    shards: &ShardIndexCache,
    e: &BatchEntry,
) -> Result<EntryReader, String> {
    match &e.archpath {
        None => store.open_entry(&e.bucket, &e.obj).map_err(|err| match err {
            StoreError::NotFound(k) => format!("missing object {k}"),
            StoreError::Io(io) => format!("read failure: {io}"),
        }),
        Some(member) => shards.extract(store, &e.bucket, &e.obj, member).map_err(|err| match err {
            ShardError::MemberNotFound { shard, member } => {
                format!("missing member {shard}!{member}")
            }
            ShardError::Store(StoreError::NotFound(k)) => format!("missing object {k}"),
            other => format!("read failure: {other}"),
        }),
    }
}

/// Lazily turn an [`EntryReader`] into the chunk-frame sequence a sender
/// transmits, reading at most `chunk_bytes` from disk per step — sender
/// residency is O(chunk), not O(entry). A read failure *after* the FIRST
/// frame went out surfaces as a SOFT_ERR frame: the DT fails the slot
/// promptly and, if bytes were already consumed there, repairs it via the
/// ranged GFN splice.
fn reader_frames<'a>(
    req_id: u64,
    index: u32,
    reader: EntryReader,
    chunk_bytes: usize,
    metrics: &'a GetBatchMetrics,
) -> impl Iterator<Item = Frame> + 'a {
    let chunk_bytes = chunk_bytes.max(1);
    let total = reader.len();
    let single = total <= chunk_bytes as u64;
    let mut reader = Some(reader);
    let mut off: u64 = 0;
    std::iter::from_fn(move || {
        let rdr = reader.as_mut()?;
        if single {
            let f = match rdr.read_chunk(chunk_bytes) {
                Ok(bytes) => Frame::data(req_id, index, bytes),
                Err(e) => Frame::soft_err(req_id, index, &format!("read failure: {e}")),
            };
            reader = None;
            metrics.sender_peak_buffer.set_max(f.payload.len() as i64);
            return Some(f);
        }
        let first = off == 0;
        match rdr.read_chunk(chunk_bytes) {
            Ok(bytes) => {
                metrics.sender_peak_buffer.set_max(bytes.len() as i64);
                off += bytes.len() as u64;
                let last = off >= total;
                if last {
                    reader = None;
                }
                Some(if first {
                    Frame::data_first_chunk(req_id, index, total, &bytes, last)
                } else {
                    Frame::data_chunk(req_id, index, bytes, last)
                })
            }
            Err(e) => {
                reader = None;
                Some(Frame::soft_err(req_id, index, &format!("read failure: {e}")))
            }
        }
    })
}

/// The frame sequence for one resolved entry (or its SOFT_ERR). Bumps the
/// per-entry sender metrics as a side effect.
fn entry_frames<'a>(
    req_id: u64,
    index: u32,
    resolved: Result<EntryReader, String>,
    chunk_bytes: usize,
    metrics: &'a GetBatchMetrics,
    satisfied: &'a Cell<u32>,
) -> Box<dyn Iterator<Item = Frame> + 'a> {
    match resolved {
        Ok(reader) => {
            satisfied.set(satisfied.get() + 1);
            metrics.sender_entries.inc();
            metrics.sender_chunks.add(chunk_count(reader.len() as usize, chunk_bytes) as u64);
            Box::new(reader_frames(req_id, index, reader, chunk_bytes, metrics))
        }
        Err(reason) => Box::new(std::iter::once(Frame::soft_err(req_id, index, &reason))),
    }
}

/// Execute a sender activation: stream every locally-owned entry to the DT,
/// then emit SENDER_DONE. Runs on the target's background pool. Entries
/// stream lazily (`send_iter`) so transmission overlaps the next disk read;
/// entries larger than `cfg.chunk_bytes` are split into chunk frames read
/// straight off an [`EntryReader`], so the DT can emit them before their
/// last byte arrives, sender residency stays O(chunk) instead of O(object),
/// and DT-side memory backpressure (its budget stalling our socket) pauses
/// us between chunks *and between disk reads* instead of after whole
/// objects.
pub fn run_sender(
    act: &SenderActivate,
    smap: &Smap,
    self_target: usize,
    store: &Arc<ObjectStore>,
    shards: &ShardIndexCache,
    pool: &Arc<PeerPool>,
    metrics: &GetBatchMetrics,
    cfg: &GetBatchConfig,
    readahead: Option<&crate::util::threadpool::ThreadPool>,
) {
    let mine = placement::local_entries(smap, &act.request, self_target);
    if mine.is_empty() {
        // Still signal DONE so the DT's completion accounting balances.
        let _ = pool.send(&act.dt_peer, &[Frame::sender_done(act.req_id, 0)]);
        return;
    }

    // Read-ahead workers warm the page cache for upcoming local reads
    // (§2.4.3). Best-effort: errors surface on the real read below.
    if let Some(ra) = readahead {
        for (_, e) in mine.iter().skip(1).take(8) {
            let store = Arc::clone(store);
            let bucket = e.bucket.clone();
            let obj = e.obj.clone();
            ra.execute(move || {
                // Touch the head of the file; the OS pulls pages in.
                let _ = store.get_range(&bucket, &obj, 0, store.size(&bucket, &obj).unwrap_or(0).min(256 << 10));
            });
        }
    }

    let req_id = act.req_id;
    let chunk_bytes = cfg.chunk_bytes.max(1);
    let satisfied = Cell::new(0u32);
    // Fully lazy: each entry is opened as a streaming reader when its first
    // frame is cut, and each chunk is read from disk only when transmitted —
    // sender residency is O(chunk_bytes) regardless of entry size.
    let data_frames = mine
        .iter()
        .flat_map(|(idx, e)| {
            entry_frames(req_id, *idx, resolve_entry(store, shards, e), chunk_bytes, metrics, &satisfied)
        });
    // Chain SENDER_DONE after the last entry on the same connection so the
    // DT observes completion only after all data frames. `once_with` defers
    // building it until the lazy entry stream has fully run, so the
    // satisfied count is final.
    let all = data_frames.chain(std::iter::once_with(|| Frame::sender_done(req_id, satisfied.get())));
    if pool.send_iter(&act.dt_peer, all).is_err() {
        // P2P path down: the DT's sender-wait timeout + GFN recovery take
        // over; nothing else to do here.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::request::BatchRequest;
    use crate::cluster::smap::NodeInfo;
    use crate::tar::{write_archive, Entry};
    use std::path::PathBuf;
    use std::time::Duration;

    fn setup(name: &str) -> (Arc<ObjectStore>, ShardIndexCache, PathBuf) {
        let base = std::env::temp_dir().join(format!("gbsend-{}-{}", std::process::id(), name));
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&base).unwrap();
        (Arc::new(ObjectStore::open(&base, 2).unwrap()), ShardIndexCache::new(16), base)
    }

    #[test]
    fn resolve_object_and_member() {
        let (store, shards, base) = setup("resolve");
        store.put("b", "o", b"data").unwrap();
        let archive = write_archive(&[Entry { name: "m.wav".into(), data: vec![7; 10] }]).unwrap();
        store.put("b", "s.tar", &archive).unwrap();

        let r = resolve_entry(&store, &shards, &BatchEntry::obj("b", "o")).unwrap();
        assert_eq!(r.len(), 4, "length known before any byte is read");
        assert_eq!(r.read_all().unwrap(), b"data");
        assert_eq!(
            resolve_entry(&store, &shards, &BatchEntry::member("b", "s.tar", "m.wav"))
                .unwrap()
                .read_all()
                .unwrap(),
            vec![7; 10]
        );
        let e = resolve_entry(&store, &shards, &BatchEntry::obj("b", "nope")).unwrap_err();
        assert!(e.starts_with("missing object"), "{e}");
        let e =
            resolve_entry(&store, &shards, &BatchEntry::member("b", "s.tar", "zz")).unwrap_err();
        assert!(e.starts_with("missing member"), "{e}");
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn sender_streams_local_entries_and_done() {
        let (store, shards, base) = setup("stream");
        // single-target smap: this sender owns everything
        let smap = Smap::new(
            1,
            vec![],
            vec![NodeInfo { id: "t0".into(), http_addr: String::new(), p2p_addr: String::new() }],
        );
        for i in 0..5 {
            store.put("b", &format!("o{i}"), format!("payload-{i}").as_bytes()).unwrap();
        }
        store.put("b", "gone", b"x").unwrap();
        store.delete("b", "gone").unwrap();

        let (tx, rx) = std::sync::mpsc::channel();
        let tx = std::sync::Mutex::new(tx);
        let p2p = crate::transport::P2pServer::serve(
            Arc::new(move |f| {
                let _ = tx.lock().unwrap().send(f);
            }),
            "dt",
        )
        .unwrap();
        let pool = PeerPool::new(Duration::from_secs(5));
        let metrics = GetBatchMetrics::new();

        let mut entries: Vec<BatchEntry> =
            (0..5).map(|i| BatchEntry::obj("b", &format!("o{i}"))).collect();
        entries.push(BatchEntry::obj("b", "gone"));
        let act = SenderActivate {
            req_id: 11,
            dt_peer: p2p.addr.to_string(),
            request: BatchRequest::new(entries),
        };
        run_sender(&act, &smap, 0, &store, &shards, &pool, &metrics, &GetBatchConfig::default(), None);

        let mut data = 0;
        let mut soft = 0;
        let mut done = 0;
        for _ in 0..7 {
            let f = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert_eq!(f.req_id, 11);
            match f.ftype {
                crate::proto::frame::FrameType::Data => {
                    assert_eq!(
                        f.payload,
                        format!("payload-{}", f.index).as_bytes(),
                        "index/payload aligned"
                    );
                    data += 1;
                }
                crate::proto::frame::FrameType::SoftErr => {
                    assert_eq!(f.index, 5);
                    soft += 1;
                }
                crate::proto::frame::FrameType::SenderDone => {
                    assert_eq!(f.index, 5, "satisfied count");
                    done += 1;
                }
            }
        }
        assert_eq!((data, soft, done), (5, 1, 1));
        assert_eq!(metrics.sender_entries.get(), 5);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn large_objects_stream_as_chunks_and_reassemble() {
        let (store, shards, base) = setup("chunks");
        let smap = Smap::new(
            1,
            vec![],
            vec![NodeInfo { id: "t0".into(), http_addr: String::new(), p2p_addr: String::new() }],
        );
        let mut rng = crate::util::rng::Rng::new(77);
        let mut big = vec![0u8; 300 << 10]; // 300 KiB ≫ 32 KiB chunks
        rng.fill_bytes(&mut big);
        store.put("b", "big", &big).unwrap();
        store.put("b", "small", b"tiny").unwrap();

        // Receive through a real DT registry so the chunk path is exercised
        // end-to-end: sender → frames → dispatch → reorder buffer.
        let registry = crate::dt::exec::DtRegistry::new();
        let entries =
            vec![BatchEntry::obj("b", "big"), BatchEntry::obj("b", "small")];
        let request = BatchRequest::new(entries);
        let exec = registry.register(crate::dt::exec::DtExec::new(21, request.clone(), 1));
        let reg2 = Arc::clone(&registry);
        let p2p =
            crate::transport::P2pServer::serve(Arc::new(move |f| reg2.dispatch(f)), "dt").unwrap();
        let pool = PeerPool::new(Duration::from_secs(5));
        let metrics = GetBatchMetrics::new();
        let cfg = GetBatchConfig { chunk_bytes: 32 << 10, ..Default::default() };
        let act = SenderActivate { req_id: 21, dt_peer: p2p.addr.to_string(), request };
        run_sender(&act, &smap, 0, &store, &shards, &pool, &metrics, &cfg, None);

        match exec.buf.wait_take(0, Duration::from_secs(5)) {
            crate::dt::order::SlotWait::Ready(d) => assert_eq!(d, big),
            other => panic!("big: {other:?}"),
        }
        match exec.buf.wait_take(1, Duration::from_secs(5)) {
            crate::dt::order::SlotWait::Ready(d) => assert_eq!(d, b"tiny"),
            other => panic!("small: {other:?}"),
        }
        assert!(metrics.sender_chunks.get() >= 10, "big object split into ≥10 chunks");
        // Streaming reads: the sender never materialized more than ~one
        // chunk of the 300 KiB entry at a time.
        let peak = metrics.sender_peak_buffer.get();
        assert!(peak > 0, "peak buffer recorded");
        assert!(
            peak <= 2 * (32 << 10),
            "sender residency {peak} exceeded 2x chunk_bytes"
        );
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn sender_with_no_local_entries_sends_done_only() {
        let (store, shards, base) = setup("empty");
        // two targets; choose the one that owns nothing for this request
        let smap = Smap::new(
            1,
            vec![],
            (0..2)
                .map(|i| NodeInfo {
                    id: format!("t{i}"),
                    http_addr: String::new(),
                    p2p_addr: String::new(),
                })
                .collect(),
        );
        let req = BatchRequest::new(vec![BatchEntry::obj("b", "o1")]);
        let owner = placement::entry_owner(&smap, &req.entries[0]);
        let other = 1 - owner;

        let (tx, rx) = std::sync::mpsc::channel();
        let tx = std::sync::Mutex::new(tx);
        let p2p = crate::transport::P2pServer::serve(
            Arc::new(move |f| {
                let _ = tx.lock().unwrap().send(f);
            }),
            "dt",
        )
        .unwrap();
        let pool = PeerPool::new(Duration::from_secs(5));
        let metrics = GetBatchMetrics::new();
        let act = SenderActivate { req_id: 9, dt_peer: p2p.addr.to_string(), request: req };
        run_sender(&act, &smap, other, &store, &shards, &pool, &metrics, &GetBatchConfig::default(), None);
        let f = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(f.ftype, crate::proto::frame::FrameType::SenderDone);
        assert_eq!(f.index, 0);
        std::fs::remove_dir_all(base).unwrap();
    }
}
