//! The cluster map: which proxies and targets exist and where they listen.
//! Versioned so placement decisions are taken "under the current cluster
//! membership" (§2.3.1). Serializable for SDK bootstrap (`GET /v1/cluster/smap`).

use crate::util::hrw;
use crate::util::json::Value;

/// One node's identity + endpoints.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeInfo {
    pub id: String,
    /// Public HTTP endpoint (host:port).
    pub http_addr: String,
    /// Intra-cluster P2P endpoint (targets only; empty for proxies).
    pub p2p_addr: String,
}

impl NodeInfo {
    fn to_json(&self) -> Value {
        Value::obj()
            .set("id", Value::str(&self.id))
            .set("http", Value::str(&self.http_addr))
            .set("p2p", Value::str(&self.p2p_addr))
    }
    fn from_json(v: &Value) -> Option<NodeInfo> {
        Some(NodeInfo {
            id: v.str_field("id")?.to_string(),
            http_addr: v.str_field("http")?.to_string(),
            p2p_addr: v.str_field("p2p").unwrap_or("").to_string(),
        })
    }
}

/// Versioned cluster map.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Smap {
    pub version: u64,
    pub proxies: Vec<NodeInfo>,
    pub targets: Vec<NodeInfo>,
    /// Precomputed HRW hashes of target ids (index-aligned with `targets`).
    target_hashes: Vec<u64>,
}

impl Smap {
    pub fn new(version: u64, proxies: Vec<NodeInfo>, targets: Vec<NodeInfo>) -> Smap {
        let target_hashes = targets.iter().map(|t| hrw::fnv1a(t.id.as_bytes())).collect();
        Smap { version, proxies, targets, target_hashes }
    }

    pub fn target_hashes(&self) -> &[u64] {
        &self.target_hashes
    }

    pub fn target_index(&self, id: &str) -> Option<usize> {
        self.targets.iter().position(|t| t.id == id)
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("version", Value::num(self.version as f64))
            .set("proxies", Value::Arr(self.proxies.iter().map(|n| n.to_json()).collect()))
            .set("targets", Value::Arr(self.targets.iter().map(|n| n.to_json()).collect()))
    }

    pub fn from_json(v: &Value) -> Option<Smap> {
        let proxies = v
            .get("proxies")?
            .as_arr()?
            .iter()
            .map(NodeInfo::from_json)
            .collect::<Option<Vec<_>>>()?;
        let targets = v
            .get("targets")?
            .as_arr()?
            .iter()
            .map(NodeInfo::from_json)
            .collect::<Option<Vec<_>>>()?;
        Some(Smap::new(v.u64_field("version")?, proxies, targets))
    }

    pub fn from_body(b: &[u8]) -> Option<Smap> {
        Smap::from_json(&Value::parse(std::str::from_utf8(b).ok()?).ok()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn smap(n: usize) -> Smap {
        let targets = (0..n)
            .map(|i| NodeInfo {
                id: format!("t{i}"),
                http_addr: format!("127.0.0.1:{}", 9000 + i),
                p2p_addr: format!("127.0.0.1:{}", 9500 + i),
            })
            .collect();
        let proxies = vec![NodeInfo {
            id: "p0".into(),
            http_addr: "127.0.0.1:8080".into(),
            p2p_addr: String::new(),
        }];
        Smap::new(1, proxies, targets)
    }

    #[test]
    fn json_roundtrip() {
        let s = smap(4);
        let body = s.to_json().to_string();
        let back = Smap::from_body(body.as_bytes()).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.target_hashes().len(), 4);
    }

    #[test]
    fn target_index_lookup() {
        let s = smap(3);
        assert_eq!(s.target_index("t2"), Some(2));
        assert_eq!(s.target_index("zz"), None);
    }

    #[test]
    fn hashes_follow_ids() {
        let s = smap(2);
        assert_eq!(s.target_hashes()[0], crate::util::hrw::fnv1a(b"t0"));
    }
}
