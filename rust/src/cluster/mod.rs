//! Cluster assembly: the versioned cluster map (smap), HRW object placement
//! over it, and the node runtime wiring stores, gateways, DT machinery and
//! the P2P transport into a runnable in-process cluster.

pub mod smap;
pub mod placement;
pub mod node;

pub use node::{Cluster, ClusterSpec};
pub use smap::{NodeInfo, Smap};
