//! Object → target placement via HRW over the smap. All nodes (proxies,
//! targets, clients) compute placement independently and agree — that's what
//! lets senders "independently determine which request entries [they] can
//! satisfy locally" (§2.3.1 phase 2) with no coordination.

use crate::batch::request::{BatchEntry, BatchRequest};
use crate::util::hrw;

use super::smap::Smap;

/// Index of the target that owns `location_key` ("bucket/objname").
pub fn owner(smap: &Smap, location_key: &str) -> usize {
    hrw::pick(location_key, smap.target_hashes())
}

/// Owner of a batch entry (shard members live with their shard).
pub fn entry_owner(smap: &Smap, e: &BatchEntry) -> usize {
    owner(smap, &e.location_key())
}

/// Ranked owner list for GFN recovery — next-best targets for the key.
pub fn ranked(smap: &Smap, location_key: &str) -> Vec<usize> {
    hrw::rank(location_key, smap.target_hashes())
}

/// Per-target placement weights for a request: how many entries each target
/// owns. The colocation-aware DT selection picks the argmax (§2.4.1).
pub fn placement_weights(smap: &Smap, req: &BatchRequest) -> Vec<u32> {
    let mut w = vec![0u32; smap.targets.len()];
    for e in &req.entries {
        w[entry_owner(smap, e)] += 1;
    }
    w
}

/// Colocation-aware DT choice: target owning the largest entry count
/// (ties → lowest index, deterministic).
pub fn colocated_dt(smap: &Smap, req: &BatchRequest) -> usize {
    let w = placement_weights(smap, req);
    w.iter().enumerate().max_by_key(|&(i, c)| (c, std::cmp::Reverse(i))).map(|(i, _)| i).unwrap_or(0)
}

/// The entries of `req` owned by target `tidx`, with their request indices.
pub fn local_entries<'r>(
    smap: &Smap,
    req: &'r BatchRequest,
    tidx: usize,
) -> Vec<(u32, &'r BatchEntry)> {
    req.entries
        .iter()
        .enumerate()
        .filter(|(_, e)| entry_owner(smap, e) == tidx)
        .map(|(i, e)| (i as u32, e))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::smap::NodeInfo;

    fn smap(n: usize) -> Smap {
        Smap::new(
            1,
            vec![],
            (0..n)
                .map(|i| NodeInfo {
                    id: format!("t{i}"),
                    http_addr: String::new(),
                    p2p_addr: String::new(),
                })
                .collect(),
        )
    }

    fn req(n: usize) -> BatchRequest {
        BatchRequest::new((0..n).map(|i| BatchEntry::obj("b", &format!("o{i}"))).collect())
    }

    #[test]
    fn partition_is_total_and_disjoint() {
        let s = smap(5);
        let r = req(200);
        let mut seen = vec![false; 200];
        for t in 0..5 {
            for (i, _) in local_entries(&s, &r, t) {
                assert!(!seen[i as usize], "entry {i} owned twice");
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every entry owned exactly once");
    }

    #[test]
    fn weights_sum_to_entries() {
        let s = smap(7);
        let r = req(300);
        let w = placement_weights(&s, &r);
        assert_eq!(w.iter().sum::<u32>(), 300);
        // roughly uniform
        for (i, &c) in w.iter().enumerate() {
            assert!(c > 10, "target {i} starved: {c}");
        }
    }

    #[test]
    fn colocated_dt_is_argmax() {
        let s = smap(4);
        // All entries are members of ONE shard → one owner dominates.
        let r = BatchRequest::new(
            (0..64).map(|i| BatchEntry::member("b", "big.tar", &format!("m{i}"))).collect(),
        );
        let dt = colocated_dt(&s, &r);
        assert_eq!(dt, owner(&s, "b/big.tar"));
        let w = placement_weights(&s, &r);
        assert_eq!(w[dt], 64);
    }

    #[test]
    fn shard_members_colocate_with_shard() {
        let s = smap(6);
        let shard_owner = owner(&s, "b/s.tar");
        for m in 0..20 {
            let e = BatchEntry::member("b", "s.tar", &format!("m{m}"));
            assert_eq!(entry_owner(&s, &e), shard_owner);
        }
    }

    #[test]
    fn ranked_first_is_owner() {
        let s = smap(5);
        for k in 0..30 {
            let key = format!("b/o{k}");
            assert_eq!(ranked(&s, &key)[0], owner(&s, &key));
        }
    }
}
