//! Node runtime: boots an in-process AIStore-like cluster — N target nodes
//! (each with its own object store, DT registry, P2P endpoint and HTTP
//! server) plus M stateless proxies — and wires the GetBatch execution flow
//! across them. Every byte moves over real localhost TCP; nothing is
//! shortcut in-process.

use std::path::PathBuf;
use std::sync::Arc;

use crate::config::ClusterConfig;
use crate::dt::admission::{Admission, Admit, MemoryBudget, Priority, TenantLedger};
use crate::util::error as anyhow;
use crate::dt::exec::{assemble, AssembleCtx, DtExec, DtRegistry};
use crate::gateway::proxy::{make_proxy_handler, ProxyState, SmapHolder};
use crate::metrics::{GetBatchMetrics, Registry};
use crate::proto::http::{Body, Handler, HttpClient, HttpServer, Request, Response};
use crate::proto::wire::{self, paths, DtRegister, SenderActivate};
use crate::sender::run_sender;
use crate::store::{
    Backend, CachedBackend, ChunkCache, ObjectStore, RemoteBackend, ShardIndexCache, StoreError,
    TailConfig,
};
use crate::transport::{P2pServer, PeerPool, ReactorConfig};
use crate::util::clock::{Clock, RealClock};
use crate::util::threadpool::ThreadPool;

use super::placement;
use super::smap::{NodeInfo, Smap};

/// How a cluster is shaped; thin alias over `ClusterConfig` for the API.
pub type ClusterSpec = ClusterConfig;

/// One storage target node.
pub struct TargetNode {
    pub info: NodeInfo,
    pub idx: usize,
    pub store: Arc<ObjectStore>,
    pub shards: Arc<ShardIndexCache>,
    /// The node's read-through chunk cache (shared by every cached bucket
    /// stack routed on this target).
    pub cache: Arc<ChunkCache>,
    pub registry: Arc<DtRegistry>,
    pub peer_pool: Arc<PeerPool>,
    pub metrics: Arc<GetBatchMetrics>,
    /// Enforced data-plane memory budget (peak/used visible for tests and
    /// diagnostics).
    pub budget: Arc<MemoryBudget>,
    // Keep servers alive; drop order stops accept loops first.
    _http: HttpServer,
    _p2p: P2pServer,
    _bg: Arc<ThreadPool>,
}

/// One gateway node.
pub struct ProxyNode {
    pub info: NodeInfo,
    pub state: Arc<ProxyState>,
    _http: HttpServer,
}

/// A running in-process cluster.
pub struct Cluster {
    pub smap: Arc<Smap>,
    pub targets: Vec<TargetNode>,
    pub proxies: Vec<ProxyNode>,
    pub registry: Arc<Registry>,
    pub cfg: ClusterConfig,
    root: PathBuf,
    owns_root: bool,
}

impl Cluster {
    /// Boot a cluster per `cfg`. Stores live under `cfg.root_dir` (or a
    /// fresh temp dir, removed on drop).
    pub fn start(cfg: ClusterConfig) -> anyhow::Result<Cluster> {
        // Enforce knob relationships once, up front: every consumer below
        // (budget, senders, DT-local chunking) sees consistent values.
        let cfg = ClusterConfig { getbatch: cfg.getbatch.sanitized(), ..cfg };
        let (root, owns_root) = if cfg.root_dir.is_empty() {
            let p = std::env::temp_dir().join(format!(
                "getbatch-{}-{:x}",
                std::process::id(),
                crate::util::rng::mix64(std::time::SystemTime::now().elapsed().map(|d| d.as_nanos() as u64).unwrap_or(0) ^ (&cfg as *const _ as u64))
            ));
            (p, true)
        } else {
            (PathBuf::from(&cfg.root_dir), false)
        };
        std::fs::create_dir_all(&root)?;

        let registry = Registry::new();
        let smap_holder = SmapHolder::new();
        let clock: Arc<dyn Clock> = Arc::new(RealClock::default());

        // ---- targets -------------------------------------------------------
        let mut targets = Vec::with_capacity(cfg.targets);
        for i in 0..cfg.targets {
            let id = format!("t{i}");
            let metrics = registry.node(&id);
            let store = Arc::new(ObjectStore::open(&root.join(&id), cfg.mountpaths)?);
            let shards = Arc::new(ShardIndexCache::new(256));
            // Tiered store wiring: one chunk cache per target; every bucket
            // with an explicit spec gets its backend stack installed on the
            // router (local is the implicit default).
            let cache = Arc::new(ChunkCache::new(
                cfg.getbatch.cache_bytes,
                cfg.getbatch.chunk_bytes,
                Some(Arc::clone(&metrics)),
            ));
            for spec in &cfg.getbatch.buckets {
                match bucket_stack(spec, &store, &cache, &cfg.getbatch, &metrics) {
                    Ok(Some(stack)) => store.route_bucket(&spec.name, stack),
                    Ok(None) => {}
                    // Misrouting a bucket silently (e.g. serving an empty
                    // local dir where remote data was meant) is worse than
                    // refusing to boot.
                    Err(e) => return Err(anyhow::Error::msg(format!("bucket '{}': {e}", spec.name))),
                }
            }
            // Registrations whose client never arrives at the stream
            // endpoint are reaped after this TTL (generous for redirect
            // latency, short enough not to pin the memory budget).
            let abandon_ttl =
                cfg.getbatch.sender_wait * 2 + std::time::Duration::from_secs(60);
            let dt_registry = DtRegistry::with_config(abandon_ttl, Some(Arc::clone(&metrics)));
            let peer_pool = PeerPool::new(cfg.p2p_idle_timeout);
            let bg = Arc::new(ThreadPool::new(cfg.http_workers.max(4), &format!("{id}-bg")));
            // Node-wide enforced data-plane memory budget: all of this
            // target's in-flight DT reorder buffers (and ranged GFN
            // recovery) reserve against it. Patience is the configured
            // producer-blocking window before a forced admission.
            let budget = MemoryBudget::with_patience(
                cfg.getbatch.dt_buffer_bytes,
                cfg.getbatch.chunk_bytes as u64,
                cfg.getbatch.budget_patience,
                Some(Arc::clone(&metrics)),
            );
            // Multi-tenant QoS: weighted fair-share ledger over the same
            // (budget, chunk) geometry, so "every active tenant at its
            // share" sums to exactly the budget's usable cap.
            let ledger = TenantLedger::new(
                cfg.getbatch.dt_buffer_bytes,
                cfg.getbatch.chunk_bytes as u64,
                cfg.getbatch.tenant_weight_map(),
                Some(Arc::clone(&metrics)),
            );

            // P2P fan-in: frames go straight to the DT registry.
            let reg2 = Arc::clone(&dt_registry);
            let p2p = P2pServer::serve_opts(
                Arc::new(move |f| reg2.dispatch(f)),
                &id,
                reactor_config(&cfg, &metrics),
            )?;

            let tstate = Arc::new(TargetState {
                id: id.clone(),
                idx: i,
                smap: Arc::clone(&smap_holder),
                store: Arc::clone(&store),
                shards: Arc::clone(&shards),
                cache: Arc::clone(&cache),
                registry: Arc::clone(&dt_registry),
                peer_pool: Arc::clone(&peer_pool),
                metrics: Arc::clone(&metrics),
                bg: Arc::clone(&bg),
                admission: Admission::new(cfg.getbatch.clone(), Arc::clone(&metrics), Arc::clone(&clock)),
                budget: Arc::clone(&budget),
                ledger,
                cfg: cfg.clone(),
                clock: Arc::clone(&clock),
                http: HttpClient::new(true),
            });
            let http =
                HttpServer::serve_opts(make_target_handler(tstate), &id, reactor_config(&cfg, &metrics))?;

            targets.push(TargetNode {
                info: NodeInfo {
                    id,
                    http_addr: http.addr.to_string(),
                    p2p_addr: p2p.addr.to_string(),
                },
                idx: i,
                store,
                shards,
                cache,
                registry: dt_registry,
                peer_pool,
                metrics,
                budget,
                _http: http,
                _p2p: p2p,
                _bg: bg,
            });
        }

        // ---- proxies -------------------------------------------------------
        let mut proxies = Vec::with_capacity(cfg.proxies);
        for i in 0..cfg.proxies {
            let id = format!("p{i}");
            let metrics = registry.node(&id);
            let state = ProxyState::new(&id, Arc::clone(&smap_holder), Arc::clone(&metrics));
            let http = HttpServer::serve_opts(
                make_proxy_handler(Arc::clone(&state)),
                &id,
                reactor_config(&cfg, &metrics),
            )?;
            proxies.push(ProxyNode {
                info: NodeInfo { id, http_addr: http.addr.to_string(), p2p_addr: String::new() },
                state,
                _http: http,
            });
        }

        // ---- publish membership ---------------------------------------------
        let smap = Arc::new(Smap::new(
            1,
            proxies.iter().map(|p| p.info.clone()).collect(),
            targets.iter().map(|t| t.info.clone()).collect(),
        ));
        smap_holder.set(Arc::clone(&smap));

        Ok(Cluster { smap, targets, proxies, registry, cfg, root, owns_root })
    }

    /// Any proxy's public address (round-robin handled by caller/SDK).
    pub fn proxy_addr(&self) -> String {
        self.proxies[0].info.http_addr.clone()
    }

    pub fn target_addr(&self, i: usize) -> String {
        self.targets[i].info.http_addr.clone()
    }

    /// Direct-put into a target-local store, bypassing HTTP *and* bucket
    /// routing — bulk dataset staging for benchmarks. Placement-faithful:
    /// writes to the HRW owner's local tier.
    pub fn put_direct(&self, bucket: &str, obj: &str, data: &[u8]) -> anyhow::Result<()> {
        let owner = placement::owner(&self.smap, &format!("{bucket}/{obj}"));
        self.targets[owner].store.local().put(bucket, obj, data)?;
        Ok(())
    }

    /// Route `bucket` on **every** target to a remote backend over the
    /// endpoint set `addrs` (targets or proxies of another cluster — all
    /// serving the same data), optionally fronted by each target's chunk
    /// cache — how endpoints only known at runtime (ephemeral ports) are
    /// attached after boot; config-time routing uses
    /// `GetBatchConfig::buckets`. Reads select among healthy endpoints and
    /// fail over per `endpoint_failure_limit` / `endpoint_probe_ms`;
    /// straggling reads are hedged per `hedge_quantile` / `hedge_min_ms` /
    /// `hedge_max_inflight`, with slow-not-dead endpoints deprioritized
    /// past `endpoint_slow_ms`.
    ///
    /// Panics if `addrs` is empty — an endpoint-less remote bucket cannot
    /// serve anything (the config path rejects the same misconfiguration
    /// at boot).
    pub fn route_remote_bucket(&self, bucket: &str, addrs: &[&str], cached: bool) {
        for t in &self.targets {
            self.route_remote_bucket_on(t.idx, bucket, addrs, cached);
        }
    }

    /// [`Cluster::route_remote_bucket`] for a single target — asymmetric
    /// topologies (e.g. one node keeping a local replica of a bucket the
    /// others front remotely).
    pub fn route_remote_bucket_on(&self, target: usize, bucket: &str, addrs: &[&str], cached: bool) {
        let t = &self.targets[target];
        let gb = &self.cfg.getbatch;
        let remote: Arc<dyn Backend> = Arc::new(RemoteBackend::with_tail(
            addrs,
            gb.endpoint_failure_limit,
            gb.endpoint_probe,
            tail_config(gb),
            Some(Arc::clone(&t.metrics)),
        ));
        let stack: Arc<dyn Backend> = if cached && gb.cache_bytes > 0 {
            Arc::new(CachedBackend::new(
                remote,
                Arc::clone(&t.cache),
                gb.readahead_chunks,
                gb.coherence_grace,
            ))
        } else {
            remote
        };
        t.store.route_bucket(bucket, stack);
    }

    pub fn root(&self) -> &PathBuf {
        &self.root
    }
}

/// Reactor shape for a node's public servers (HTTP and P2P): event-loop
/// count and connection ceiling from the cluster config, worker floor from
/// the legacy `http_workers` knob (the pool still grows elastically under
/// load), node metrics wired through so `open_connections` /
/// `reactor_wakeups_total` / `accept_backlog_shed_total` are reported.
fn reactor_config(cfg: &ClusterConfig, metrics: &Arc<GetBatchMetrics>) -> ReactorConfig {
    ReactorConfig {
        threads: cfg.reactor_threads,
        max_connections: cfg.max_connections,
        min_workers: cfg.http_workers.max(1),
        metrics: Some(Arc::clone(metrics)),
        ..Default::default()
    }
}

/// Build the backend stack a [`crate::config::BucketSpec`] describes:
/// `Ok(None)` when the spec reduces to the default (plain local,
/// uncached), `Err` when the spec is invalid — a misconfigured bucket
/// must refuse to boot, not silently serve the wrong tier.
/// The tail-latency policy a node's remote backends run under, straight
/// from the config section (`endpoint_slow_ms`, `hedge_quantile`,
/// `hedge_min_ms`, `hedge_max_inflight`).
fn tail_config(gb: &crate::config::GetBatchConfig) -> TailConfig {
    TailConfig {
        slow: gb.endpoint_slow,
        hedge_quantile: gb.hedge_quantile,
        hedge_min: gb.hedge_min,
        hedge_max_inflight: gb.hedge_max_inflight,
    }
}

fn bucket_stack(
    spec: &crate::config::BucketSpec,
    store: &Arc<ObjectStore>,
    cache: &Arc<ChunkCache>,
    gb: &crate::config::GetBatchConfig,
    metrics: &Arc<GetBatchMetrics>,
) -> Result<Option<Arc<dyn Backend>>, String> {
    let base: Arc<dyn Backend> = match spec.backend.as_str() {
        "remote" if !spec.remote_addrs.is_empty() => {
            let addrs: Vec<&str> = spec.remote_addrs.iter().map(|a| a.as_str()).collect();
            Arc::new(RemoteBackend::with_tail(
                &addrs,
                gb.endpoint_failure_limit,
                gb.endpoint_probe,
                tail_config(gb),
                Some(Arc::clone(metrics)),
            ))
        }
        "remote" => return Err("backend \"remote\" requires remote_addrs".into()),
        "local" | "" => Arc::clone(store.local()) as Arc<dyn Backend>,
        other => return Err(format!("unknown backend \"{other}\" (expected local|remote)")),
    };
    Ok(if spec.cache && gb.cache_bytes > 0 {
        Some(Arc::new(CachedBackend::new(
            base,
            Arc::clone(cache),
            gb.readahead_chunks,
            gb.coherence_grace,
        )))
    } else if spec.backend == "remote" {
        Some(base)
    } else {
        None
    })
}

impl Drop for Cluster {
    fn drop(&mut self) {
        if self.owns_root {
            let _ = std::fs::remove_dir_all(&self.root);
        }
    }
}

// ---------------------------------------------------------------- target --

struct TargetState {
    id: String,
    idx: usize,
    smap: Arc<SmapHolder>,
    store: Arc<ObjectStore>,
    shards: Arc<ShardIndexCache>,
    /// The node's shared chunk cache — the `/v1/invalidate` handler drops
    /// an object's chunks here when another node writes it.
    cache: Arc<ChunkCache>,
    registry: Arc<DtRegistry>,
    peer_pool: Arc<PeerPool>,
    metrics: Arc<GetBatchMetrics>,
    bg: Arc<ThreadPool>,
    admission: Admission,
    budget: Arc<MemoryBudget>,
    /// Multi-tenant QoS: per-tenant weighted fair-share token accounting
    /// layered over `budget`.
    ledger: Arc<TenantLedger>,
    cfg: ClusterConfig,
    clock: Arc<dyn Clock>,
    /// Pooled client for intra-cluster control traffic (invalidation
    /// broadcasts).
    http: HttpClient,
}

fn make_target_handler(st: Arc<TargetState>) -> Handler {
    Arc::new(move |req: Request| target_route(&st, req))
}

fn target_route(st: &Arc<TargetState>, req: Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        (_, p) if p.starts_with(paths::OBJECTS) => target_object(st, req),
        ("POST", paths::DT_REGISTER) => target_dt_register(st, req),
        ("POST", paths::SENDER_ACTIVATE) => target_sender_activate(st, req),
        ("GET", paths::DT_STREAM) => target_dt_stream(st, req),
        // Serves this node's *local* slice only — deliberately not routed
        // through the bucket's backend stack, so a proxy fan-out over a
        // remote-routed bucket cannot recurse or list the remote endpoint
        // once per target (the remote backend's `list` targets the proxy /
        // storage node that owns the data).
        ("GET", paths::LIST) => match req.query_param("bucket") {
            Some(bucket) => match st.store.local().list(bucket) {
                Ok(names) => Response::ok(names.join("\n").into_bytes()),
                Err(e) => Response::text(500, &e.to_string()),
            },
            None => Response::text(400, "missing bucket"),
        },
        // Cache-coherence invalidation (another node wrote this object):
        // drop its cached chunks and its shard member index. Idempotent and
        // cheap when nothing is cached.
        ("POST", paths::INVALIDATE) => {
            match (req.query_param("bucket"), req.query_param("obj")) {
                (Some(bucket), Some(obj)) => {
                    st.cache.invalidate_object(bucket, obj);
                    st.shards.invalidate(bucket, obj);
                    Response::ok(Vec::new())
                }
                _ => Response::text(400, "missing bucket/obj"),
            }
        }
        // Epoch prefetch (the batch planner's warm-ahead call): pull the
        // object's chunks into this node's cache tier ahead of the demand
        // read the planner predicted. Runs inline on the handler worker —
        // the *client* keeps it off its own demand path by issuing it from
        // background planner workers.
        ("POST", paths::PREFETCH) => {
            match (req.query_param("bucket"), req.query_param("obj")) {
                (Some(bucket), Some(obj)) => {
                    st.metrics.prefetch_issued.inc();
                    if let Some(h) = req.query_param("horizon").and_then(|h| h.parse::<i64>().ok())
                    {
                        st.metrics.prefetch_horizon.set(h);
                    }
                    match st.store.prefetch(bucket, obj) {
                        Ok(filled) => Response::ok(format!("{filled}").into_bytes()),
                        Err(StoreError::NotFound(k)) => {
                            Response::text(404, &format!("object not found: {k}"))
                        }
                        Err(e) => Response::text(500, &e.to_string()),
                    }
                }
                _ => Response::text(400, "missing bucket/obj"),
            }
        }
        ("GET", paths::METRICS) => Response::ok(st.metrics.render(&st.id).into_bytes()),
        ("GET", paths::HEALTH) => Response::ok(b"ok".to_vec()),
        _ => Response::status(404),
    }
}

/// Fan a cache-invalidation out to every *other* target in the smap after
/// a successful PUT/DELETE through this node — fire-and-forget on the
/// background pool (the write response never waits on the broadcast). A
/// missed delivery is tolerated by design: versioned chunk keys make the
/// stale chunks unreachable at the peer's next metadata revalidation
/// (`coherence_grace_ms`), so the broadcast only narrows the staleness
/// window, it does not carry correctness.
fn broadcast_invalidate(st: &Arc<TargetState>, bucket: &str, obj: &str) {
    let smap = match st.smap.get() {
        Some(s) => s,
        None => return,
    };
    if smap.targets.len() <= 1 {
        return;
    }
    st.metrics.invalidate_broadcasts.inc();
    let st2 = Arc::clone(st);
    let pq = format!("{}?bucket={bucket}&obj={obj}", paths::INVALIDATE);
    st.bg.execute(move || {
        // Parallel fan-out (same shape as the proxy's): one slow or
        // partitioned peer must not delay delivery to the others — a
        // sequential walk would stretch every later peer's staleness
        // window by the stuck peer's connect timeout.
        let others: Vec<usize> =
            (0..smap.targets.len()).filter(|&i| i != st2.idx).collect();
        let width = others.len().clamp(1, 16);
        crate::util::threadpool::scoped_map(&others, width, |_, &i| {
            if let Ok(resp) = st2.http.request("POST", &smap.targets[i].http_addr, &pq, &[]) {
                let _ = resp.into_bytes();
            }
        });
    });
}

/// Local object I/O (clients arrive here via proxy redirect; GFN arrives
/// directly with `local=true`). `archpath` extracts one shard member.
///
/// GETs are fully streamed: the entry is opened as an
/// [`EntryReader`](crate::store::EntryReader) and copied to the socket in
/// `chunk_bytes` pieces — the handler never materializes an object.
/// `Range: bytes=S-E` is honored with a 206 + `content-range` response (the
/// transport ranged GFN recovery rides on).
fn target_object(st: &Arc<TargetState>, req: Request) -> Response {
    let (bucket, obj) = match wire::parse_object_path(&req.path) {
        Some(x) => x,
        None => return Response::text(400, "bad object path"),
    };
    match req.method.as_str() {
        "PUT" => match st.store.put(&bucket, &obj, &req.body) {
            Ok(()) => {
                st.shards.invalidate(&bucket, &obj);
                broadcast_invalidate(st, &bucket, &obj);
                Response::ok(Vec::new())
            }
            Err(e) => Response::text(500, &e.to_string()),
        },
        "GET" => {
            use crate::proto::http::RangeSpec;
            // Whole-object GETs and range-start-0 slices (metadata probes,
            // a recovery's first chunk) advertise the PUT-time CRC-32
            // sidecar via a stat; later per-chunk ranged GETs skip it — for
            // a remote-routed bucket it would cost one remote probe per
            // chunk. Member extraction has no per-member sidecar (the hash
            // covers the whole shard). The write generation is stamped
            // separately below, bound to the bytes the reader actually
            // holds.
            let want_meta = req.query_param("archpath").is_none()
                && matches!(
                    crate::proto::http::resolve_range(req.header("range"), u64::MAX),
                    RangeSpec::Whole | RangeSpec::Slice { start: 0, .. }
                );
            let meta = if want_meta { st.store.stat(&bucket, &obj).ok() } else { None };
            let opened = match req.query_param("archpath") {
                Some(member) => st
                    .shards
                    .extract(&st.store, &bucket, &obj, member)
                    .map_err(|e| e.to_string()),
                None => st.store.open_entry(&bucket, &obj).map_err(|e| e.to_string()),
            };
            let mut reader = match opened {
                Ok(r) => r,
                Err(e) if e.contains("not found") => return Response::text(404, &e),
                Err(e) => return Response::text(500, &e),
            };
            let len = reader.len();
            let observed = reader.observed_version();
            let chunk = st.cfg.getbatch.chunk_bytes.max(1);
            let range = crate::proto::http::resolve_range(req.header("range"), len);
            let resp = match range {
                RangeSpec::Whole => {
                    Response::stream(move |w| stream_entry(reader, len, chunk, w))
                }
                RangeSpec::Slice { start, end } => {
                    if let Err(e) = reader.seek_to(start) {
                        return Response::text(500, &e.to_string());
                    }
                    let span = end - start;
                    Response::stream(move |w| stream_entry(reader, span, chunk, w))
                        .into_partial(start, end, len)
                }
                RangeSpec::Unsatisfiable => crate::proto::http::range_unsatisfiable(len),
            };
            let mut resp = resp;
            if let Some(c) = meta.as_ref().and_then(|m| m.crc) {
                resp = resp.with_header(wire::HDR_OBJ_CRC, &format!("{c:08x}"));
            }
            // Version stamp, bound to the bytes this response streams. The
            // stat's version was read *before* the reader opened (a lower
            // bound on the bytes' generation), the reader's observation
            // *after* (an upper bound — the open handle pins one version):
            // when both exist they must agree or an overwrite raced the
            // open and the stamp is omitted (fail unconfirmed; the
            // consumer's fill gate falls back to its own probe or retries).
            // Ranged responses — which historically carried no version —
            // get the after-open observation alone: a remote fill gates on
            // "stamp == pin", and with monotonic version visibility the
            // pinned generation can only be ≤ the bytes' ≤ the stamp, so
            // equality pins the bytes exactly. Costs nothing extra: the
            // observation rides metadata the reader already holds.
            let version = match (meta.as_ref().and_then(|m| m.version), observed) {
                (Some(pre), Some(post)) => (pre == post).then_some(post),
                (Some(pre), None) => Some(pre),
                (None, post) => post,
            };
            if let Some(v) = version {
                resp = resp.with_header(wire::HDR_OBJ_VERSION, &v.to_string());
            }
            resp
        }
        "DELETE" => match st.store.delete(&bucket, &obj) {
            Ok(()) => {
                st.shards.invalidate(&bucket, &obj);
                broadcast_invalidate(st, &bucket, &obj);
                Response::ok(Vec::new())
            }
            Err(e) => Response::text(404, &e.to_string()),
        },
        _ => Response::status(400),
    }
}

/// Copy `span` bytes from an entry reader to an HTTP body sink in
/// chunk-sized pieces (bounded residency on the serving side too).
fn stream_entry(
    mut reader: crate::store::EntryReader,
    span: u64,
    chunk: usize,
    w: &mut dyn std::io::Write,
) -> std::io::Result<()> {
    let mut remaining = span;
    while remaining > 0 {
        let want = remaining.min(chunk as u64) as usize;
        let piece = reader
            .read_chunk(want)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::Other, e.to_string()))?;
        if piece.is_empty() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "entry ended before its declared length",
            ));
        }
        w.write_all(&piece)?;
        remaining -= piece.len() as u64;
    }
    Ok(())
}

/// DT admission rejection: 429 plus a `Retry-After` telling the client how
/// long a back-off is actually worth — the budget's patience window,
/// rounded up to whole seconds (the header is integral; minimum 1 so a
/// sub-second patience never advertises "retry immediately"). That window
/// is how long this node lets producers block before forcing an admission,
/// i.e. the time scale on which buffered memory realistically drains.
/// Lower priority classes are shed earlier (their thresholds sit further
/// from critical), so their hint is scaled by the class backoff factor —
/// bulk traffic backs off longest, keeping the recovered headroom for
/// interactive work. Proxies propagate the header to the client untouched.
fn reject_429(st: &Arc<TargetState>, class: Priority, msg: &str) -> Response {
    let p = st.cfg.getbatch.budget_patience;
    let secs = (p.as_secs() + u64::from(p.subsec_nanos() > 0)).max(1);
    let secs = secs.saturating_mul(class.backoff_factor());
    Response::text(429, msg).with_header("retry-after", &secs.to_string())
}

/// Priority class for one registration: the wire value when valid, else the
/// configured default (itself sanitized at config load; `Batch` as the
/// final fallback).
fn resolve_priority(st: &Arc<TargetState>, wire_priority: &str) -> Priority {
    Priority::parse(wire_priority)
        .or_else(|| Priority::parse(&st.cfg.getbatch.default_priority))
        .unwrap_or(Priority::Batch)
}

/// Phase 1: allocate per-request execution state; resolve *our own* entries
/// in the background (the DT doubles as the sender for its local items).
fn target_dt_register(st: &Arc<TargetState>, req: Request) -> Response {
    let reg = match DtRegister::from_body(&req.body) {
        Some(r) => r,
        None => return Response::text(400, "malformed dt-register"),
    };
    // Opportunistic reaping: registrations whose client never arrived at
    // the stream endpoint must not pin the shared memory budget.
    st.registry.reap_stale();
    // Memory is a hard constraint: §2.4.3. Both the buffered-bytes gate and
    // the budget-overrun gate surface as 429 (client backs off + retries).
    // Shedding is lowest-class-first: a bulk registration hits its (lower)
    // threshold while interactive traffic still admits.
    let class = resolve_priority(st, &reg.priority);
    match st.admission.check_register_class(class) {
        Admit::Ok => {}
        Admit::RejectMemory { buffered, critical } => {
            st.metrics.tenant_shed(&reg.tenant);
            return reject_429(st, class, &format!("memory pressure: {buffered}/{critical}"));
        }
        Admit::RejectOverrun { overruns, limit } => {
            st.metrics.tenant_shed(&reg.tenant);
            return reject_429(
                st,
                class,
                &format!("memory budget overrunning: {overruns} forced admissions (limit {limit})"),
            );
        }
    }
    st.metrics.dt_requests.inc();
    st.metrics.dt_inflight.add(1);
    st.metrics.tenant_admit(&reg.tenant);
    // The execution's reorder buffer reserves against the node's enforced
    // memory budget and the owning tenant's fair-share ledger — producers
    // block under pressure (§2.4.3), over-share tenants block earlier.
    let exec = st.registry.register(DtExec::with_qos(
        reg.req_id,
        reg.request,
        reg.num_senders,
        Arc::clone(&st.budget),
        st.ledger.handle(&reg.tenant),
    ));

    // DT-local resolution (runs concurrently with remote senders).
    let st2 = Arc::clone(st);
    st.bg.execute(move || {
        let smap = match st2.smap.get() {
            Some(s) => s,
            None => {
                exec.note_local_done();
                return;
            }
        };
        let mine = placement::local_entries(&smap, &exec.request, st2.idx);
        for (idx, e) in mine {
            // Soft throttle under load (CPU/disk pressure proxy): scale with
            // this node's in-flight DT executions.
            st2.admission.throttle(st2.registry.inflight() as i64);
            match crate::sender::resolve_entry(&st2.store, &st2.shards, e) {
                // Streamed like the remote-sender path: chunks are read off
                // the EntryReader one at a time and reserve budget
                // incrementally, so a large DT-local entry never has more
                // than one chunk resident outside the reorder buffer and
                // the assembler can start emitting it early.
                Ok(reader) => {
                    stream_local_entry(&exec.buf, idx, reader, st2.cfg.getbatch.chunk_bytes)
                }
                Err(reason) => exec.buf.fail(
                    idx,
                    if reason.starts_with("missing object") {
                        crate::batch::error::EntryError::NotFound(reason)
                    } else if reason.starts_with("missing member") {
                        crate::batch::error::EntryError::MemberNotFound(reason)
                    } else {
                        crate::batch::error::EntryError::ReadFailure(reason)
                    },
                ),
            }
        }
        // Completion signal: together with SENDER_DONE fan-in this lets the
        // assembler recover still-pending slots without burning the full
        // sender-wait timeout.
        exec.note_local_done();
    });
    Response::ok(Vec::new())
}

/// Deliver one DT-local entry into the reorder buffer straight off its
/// [`EntryReader`](crate::store::EntryReader), one chunk at a time — the
/// DT-local twin of the sender's streaming read path. A mid-stream read
/// failure fails the slot (recoverable; the assembler's ranged GFN takes
/// over, splicing if bytes were already consumed).
fn stream_local_entry(
    buf: &crate::dt::order::OrderBuffer,
    idx: u32,
    mut reader: crate::store::EntryReader,
    chunk_bytes: usize,
) {
    use crate::batch::error::EntryError;
    let chunk = chunk_bytes.max(1);
    let total = reader.len();
    if total <= chunk as u64 {
        match reader.read_chunk(chunk) {
            Ok(bytes) => buf.fill(idx, bytes),
            Err(e) => buf.fail(idx, EntryError::ReadFailure(format!("local read: {e}"))),
        }
        return;
    }
    let mut off = 0u64;
    while off < total {
        match reader.read_chunk(chunk) {
            Ok(bytes) => {
                let first = off == 0;
                off += bytes.len() as u64;
                buf.append_chunk(idx, total, bytes, first, off >= total);
            }
            Err(e) => {
                buf.fail(idx, EntryError::ReadFailure(format!("local read: {e}")));
                return;
            }
        }
    }
}

/// Phase 2 (receiver side): join the execution as a sender; resolve + push
/// in the background, return immediately.
fn target_sender_activate(st: &Arc<TargetState>, req: Request) -> Response {
    let act = match SenderActivate::from_body(&req.body) {
        Some(a) => a,
        None => return Response::text(400, "malformed sender-activate"),
    };
    let st2 = Arc::clone(st);
    st.bg.execute(move || {
        let smap = match st2.smap.get() {
            Some(s) => s,
            None => return,
        };
        st2.admission.throttle(st2.registry.inflight() as i64);
        let ra = None; // readahead pool shares bg; enabled in perf runs
        run_sender(
            &act,
            &smap,
            st2.idx,
            &st2.store,
            &st2.shards,
            &st2.peer_pool,
            &st2.metrics,
            &st2.cfg.getbatch,
            ra,
        );
    });
    Response::ok(Vec::new())
}

/// Phase 3: the client (redirected here by the proxy) pulls the assembled
/// stream. Streaming mode emits chunked TAR as slots resolve; buffered mode
/// assembles fully, then ships with content-length.
fn target_dt_stream(st: &Arc<TargetState>, req: Request) -> Response {
    let req_id = match req.query_param(wire::QPARAM_REQ_ID).and_then(|s| s.parse::<u64>().ok()) {
        Some(id) => id,
        None => return Response::text(400, "missing req id"),
    };
    // Atomic lookup-and-claim shields this execution from the
    // abandoned-registration reaper.
    let exec = match st.registry.claim(req_id) {
        Some(e) => e,
        None => return Response::text(404, "unknown execution"),
    };
    let smap = match st.smap.get() {
        Some(s) => s,
        None => return Response::text(503, "smap not ready"),
    };
    let ctx = AssembleCtx {
        smap,
        http: HttpClient::new(true),
        self_target: st.idx,
        cfg: st.cfg.getbatch.clone(),
        metrics: Arc::clone(&st.metrics),
        clock: Arc::clone(&st.clock),
        budget: Some(Arc::clone(&st.budget)),
    };
    let registry = Arc::clone(&st.registry);
    let metrics = Arc::clone(&st.metrics);

    if exec.request.opts.streaming {
        // Chunked: overlap retrieval, assembly and consumption (§2.4.1).
        Response::stream(move |w| {
            let r = assemble(&exec, &ctx, w);
            // Closing first lets producers still blocked on the memory
            // budget (e.g. after an abort) bail out promptly instead of
            // stalling their connection until the budget's patience expires.
            exec.buf.close();
            registry.remove(req_id);
            metrics.dt_inflight.sub(1);
            match r {
                Ok(_) => Ok(()),
                // Mid-stream abort: truncate the chunked stream — the client
                // sees a hard error, matching abort-on-error semantics.
                Err(e) => Err(std::io::Error::new(std::io::ErrorKind::Other, e.to_string())),
            }
        })
    } else {
        let mut buf = Vec::new();
        let r = assemble(&exec, &ctx, &mut buf);
        exec.buf.close();
        registry.remove(req_id);
        metrics.dt_inflight.sub(1);
        match r {
            Ok(_) => Response { status: 200, headers: vec![], body: Body::Bytes(buf) },
            Err(e) => Response::text(500, &e.to_string()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::request::{BatchEntry, BatchRequest};

    fn small_cluster() -> Cluster {
        Cluster::start(ClusterConfig { targets: 3, proxies: 1, mountpaths: 2, http_workers: 4, ..Default::default() })
            .unwrap()
    }

    #[test]
    fn boots_and_reports_smap() {
        let c = small_cluster();
        let cl = HttpClient::new(true);
        let resp = cl.get(&c.proxy_addr(), paths::SMAP).unwrap();
        assert_eq!(resp.status, 200);
        let smap = Smap::from_body(&resp.into_bytes().unwrap()).unwrap();
        assert_eq!(smap.targets.len(), 3);
        assert_eq!(smap.proxies.len(), 1);
    }

    #[test]
    fn object_put_get_via_proxy_redirect() {
        let c = small_cluster();
        let cl = HttpClient::new(true);
        let addr = c.proxy_addr();
        for i in 0..12 {
            let pq = wire::object_path("b", &format!("o{i}"));
            let resp = cl.put(&addr, &pq, format!("data-{i}").as_bytes()).unwrap();
            assert_eq!(resp.status, 200, "put o{i}");
        }
        for i in 0..12 {
            let pq = wire::object_path("b", &format!("o{i}"));
            let resp = cl.get(&addr, &pq).unwrap();
            assert_eq!(resp.status, 200);
            assert_eq!(resp.into_bytes().unwrap(), format!("data-{i}").as_bytes());
        }
        // objects actually spread across targets
        let counts: Vec<usize> =
            c.targets.iter().map(|t| t.store.list("b").unwrap().len()).collect();
        assert_eq!(counts.iter().sum::<usize>(), 12);
        assert!(counts.iter().filter(|&&n| n > 0).count() >= 2, "{counts:?}");
    }

    #[test]
    fn getbatch_end_to_end_ordering() {
        let c = small_cluster();
        let cl = HttpClient::new(true);
        let addr = c.proxy_addr();
        for i in 0..24 {
            c.put_direct("b", &format!("o{i:02}"), format!("v{i:02}").as_bytes()).unwrap();
        }
        let req = BatchRequest::new(
            (0..24).rev().map(|i| BatchEntry::obj("b", &format!("o{i:02}"))).collect(),
        );
        let resp = cl.request("GET", &addr, paths::BATCH, &req.to_body()).unwrap();
        assert_eq!(resp.status, 200);
        let items = crate::batch::reader::BatchReader::new(resp.body).collect_all().unwrap();
        assert_eq!(items.len(), 24);
        // strict request order: o23, o22, ..., o00
        for (k, item) in items.iter().enumerate() {
            let i = 23 - k;
            assert_eq!(item.name(), format!("o{i:02}"));
            assert_eq!(item.data().unwrap(), format!("v{i:02}").as_bytes());
        }
    }

    #[test]
    fn getbatch_missing_aborts_by_default() {
        let c = small_cluster();
        let cl = HttpClient::new(true);
        c.put_direct("b", "exists", b"x").unwrap();
        let req = BatchRequest::new(vec![
            BatchEntry::obj("b", "exists"),
            BatchEntry::obj("b", "does-not-exist"),
        ])
        .streaming(false);
        let resp = cl.request("GET", &c.proxy_addr(), paths::BATCH, &req.to_body()).unwrap();
        assert_eq!(resp.status, 500, "hard abort surfaces as 500 in buffered mode");
    }

    #[test]
    fn getbatch_coer_yields_placeholder() {
        let c = small_cluster();
        let cl = HttpClient::new(true);
        c.put_direct("b", "e0", b"x").unwrap();
        c.put_direct("b", "e2", b"z").unwrap();
        let req = BatchRequest::new(vec![
            BatchEntry::obj("b", "e0"),
            BatchEntry::obj("b", "missing"),
            BatchEntry::obj("b", "e2"),
        ])
        .continue_on_err(true);
        let resp = cl.request("GET", &c.proxy_addr(), paths::BATCH, &req.to_body()).unwrap();
        assert_eq!(resp.status, 200);
        let items = crate::batch::reader::BatchReader::new(resp.body).collect_all().unwrap();
        assert_eq!(items.len(), 3);
        assert!(!items[0].is_missing());
        assert!(items[1].is_missing());
        assert_eq!(items[1].name(), "missing");
        assert_eq!(items[2].data().unwrap(), b"z");
    }

    #[test]
    fn shard_members_via_getbatch() {
        let c = small_cluster();
        let cl = HttpClient::new(true);
        let entries: Vec<crate::tar::Entry> = (0..6)
            .map(|i| crate::tar::Entry { name: format!("u{i}.wav"), data: vec![i as u8; 64] })
            .collect();
        let shard = crate::tar::write_archive(&entries).unwrap();
        c.put_direct("b", "s-0.tar", &shard).unwrap();

        let req = BatchRequest::new(vec![
            BatchEntry::member("b", "s-0.tar", "u3.wav"),
            BatchEntry::member("b", "s-0.tar", "u1.wav"),
        ]);
        let resp = cl.request("GET", &c.proxy_addr(), paths::BATCH, &req.to_body()).unwrap();
        let items = crate::batch::reader::BatchReader::new(resp.body).collect_all().unwrap();
        assert_eq!(items[0].name(), "s-0.tar/u3.wav");
        assert_eq!(items[0].data().unwrap(), &vec![3u8; 64][..]);
        assert_eq!(items[1].data().unwrap(), &vec![1u8; 64][..]);
    }
}
