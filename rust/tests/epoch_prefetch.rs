//! Epoch-aware loading end to end: the deterministic global shuffle plus
//! predictive next-batch prefetch, proven against the acceptance criteria —
//! (a) a prefetch-ON epoch serves batch-N+1 chunk reads warm without extra
//! remote probes, (b) a prefetch-ON second epoch is strictly faster than
//! OFF under injected storage latency, (c) prefetch never pushes a cache
//! past `cache_bytes` and a mid-epoch overwrite invalidates prefetched
//! chunks instead of serving stale bytes.
//!
//! Topology mirrors `tiered_store.rs`: a storage cluster holds the dataset;
//! a serving cluster fronts bucket `rb` from it through per-target chunk
//! caches. Prefetch calls go client → serving proxy → (307) → the entry's
//! HRW owner target — the same node whose cache serves the demand read.

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{payload, retry_once, serving_rb, sum};
use getbatch::client::loader::{AccessMode, DataLoader, Manifest, SampleRef};
use getbatch::client::prefetch::PrefetchPlanner;
use getbatch::client::sdk::Client;
use getbatch::config::GetBatchConfig;
use getbatch::proto::http::HttpClient;
use getbatch::testutil::fixtures;
use getbatch::Cluster;

/// Stage `n` standalone objects of `size` bytes in the storage cluster's
/// `rb` bucket and return the manifest the loaders will iterate.
fn stage(storage: &Cluster, n: usize, size: usize) -> Manifest {
    let mut m = Manifest::default();
    for i in 0..n {
        let name = format!("obj-{i:03}");
        storage.put_direct("rb", &name, &payload(size, 1000 + i as u64)).unwrap();
        m.samples.push(SampleRef {
            bucket: "rb".into(),
            shard: None,
            name,
            size: size as u64,
        });
    }
    m
}

fn serving(storage_addr: &str, gb: GetBatchConfig) -> Cluster {
    serving_rb(storage_addr, 3, gb)
}

/// Drive one full epoch; with a planner attached, wait for its background
/// fills between batches so warmness is deterministic. Returns the served
/// byte sequence.
fn drive_epoch(
    dl: &mut DataLoader,
    planner: Option<&Arc<PrefetchPlanner>>,
    epoch: u64,
) -> Vec<Vec<(String, Vec<u8>)>> {
    dl.begin_epoch(epoch);
    let mut seq = Vec::new();
    while let Some((samples, _)) = dl.next_epoch_batch().unwrap() {
        seq.push(samples.into_iter().map(|s| (s.name, s.data)).collect());
        if let Some(p) = planner {
            assert!(p.wait_idle(Duration::from_secs(30)), "prefetch pool wedged");
        }
    }
    seq
}

/// (a) With `prefetch_batches ≥ 1`, a warm pipeline covers the chunk reads
/// of every batch after the first (≥ 90 % of them land on still-pinned
/// prefetched chunks) and costs zero extra remote probes versus the same
/// epoch with prefetch OFF — prefetch fills *replace* demand fills.
#[test]
fn warm_pipeline_covers_future_batches_without_extra_remote_probes() {
    let gb = GetBatchConfig {
        chunk_bytes: 16 << 10,
        dt_buffer_bytes: 256 << 10,
        cache_bytes: 4 << 20,
        readahead_chunks: 2,
        prefetch_batches: 2,
        // Long grace: the prefetch's metadata probe is reused by the
        // demand open, keeping the probe counts of both runs comparable.
        coherence_grace: Duration::from_secs(60),
        ..Default::default()
    }
    .sanitized();
    assert!(gb.prefetch_batches >= 1, "config under test must keep prefetch on");

    let storage = fixtures::cluster(1);
    // 12 objects × 40 KiB (3 chunks of 16 KiB each), batches of 4.
    let manifest = stage(&storage, 12, 40 << 10);

    // Baseline: same seed, prefetch OFF.
    let off = serving(&storage.proxy_addr(), gb.clone());
    let mut dl = DataLoader::new(
        Client::new(&off.proxy_addr()),
        manifest.clone(),
        AccessMode::GetBatch,
        4,
        99,
    );
    let seq_off = drive_epoch(&mut dl, None, 0);
    let remote_off = sum(&off, |t| t.metrics.remote_fetches.get());
    assert_eq!(sum(&off, |t| t.cache.fills_prefetch.get()), 0);

    // Prefetch ON: fresh cluster, same seed and plan.
    let on = serving(&storage.proxy_addr(), gb.clone());
    let client = Client::new(&on.proxy_addr());
    let planner = PrefetchPlanner::new(client.clone(), gb.prefetch_batches, 4);
    let mut dl = DataLoader::new(client, manifest.clone(), AccessMode::GetBatch, 4, 99);
    dl.attach_prefetch(Arc::clone(&planner));
    let seq_on = drive_epoch(&mut dl, Some(&planner), 0);

    assert_eq!(seq_on, seq_off, "same seed ⇒ byte-identical epoch, prefetch or not");
    assert_eq!(planner.failed.get(), 0, "every prefetch call landed");

    // Every batch after the first (8 objects × 3 chunks) was warmed ahead
    // of its demand read: ≥ 90 % of those chunk reads hit pinned chunks.
    let future_chunks = 8 * 3u64;
    let pf_hits = sum(&on, |t| t.cache.prefetch_hits.get());
    assert!(
        pf_hits * 10 >= future_chunks * 9,
        "prefetch covered {pf_hits}/{future_chunks} future chunk reads"
    );
    assert!(sum(&on, |t| t.cache.fills_prefetch.get()) > 0);

    // Zero extra remote probes: warming ahead re-shapes *when* the remote
    // reads happen, never how many.
    let remote_on = sum(&on, |t| t.metrics.remote_fetches.get());
    assert!(
        remote_on <= remote_off,
        "prefetch added remote probes: ON {remote_on} vs OFF {remote_off}"
    );
    // The serving nodes saw the planner's calls and horizon.
    assert!(sum(&on, |t| t.metrics.prefetch_issued.get()) >= 8);
}

/// (b) Under injected storage latency, the wall time of a *second* epoch
/// (same seed, caches invalidated between epochs so the measurement is not
/// trivially warm) is strictly lower with prefetch ON: the fills overlap
/// the per-batch compute window instead of gating the demand path.
#[test]
fn second_epoch_wall_time_prefetch_on_beats_off() {
    let gb = GetBatchConfig {
        chunk_bytes: 16 << 10,
        dt_buffer_bytes: 256 << 10,
        cache_bytes: 4 << 20,
        readahead_chunks: 2,
        prefetch_batches: 1,
        coherence_grace: Duration::from_secs(60),
        ..Default::default()
    }
    .sanitized();
    let compute = Duration::from_millis(100); // per-batch training step

    let storage = fixtures::cluster(1);
    let manifest = stage(&storage, 8, 40 << 10); // batches of 2 ⇒ 4 batches
    // Every storage read now sleeps: a cold fill is expensive, which is
    // exactly the gap prefetch exists to hide. 25 ms is deliberately large
    // relative to CI scheduling jitter so the ON/OFF gap cannot be drowned
    // out by a noisy runner.
    for t in &storage.targets {
        t.store.local().set_latency(Duration::from_millis(25), 1.0);
    }

    let run = |with_prefetch: bool| -> Duration {
        let c = serving(&storage.proxy_addr(), gb.clone());
        let client = Client::new(&c.proxy_addr());
        let mut dl =
            DataLoader::new(client.clone(), manifest.clone(), AccessMode::GetBatch, 2, 7);
        let planner = if with_prefetch {
            let p = PrefetchPlanner::new(client, gb.prefetch_batches, 4);
            dl.attach_prefetch(Arc::clone(&p));
            Some(p)
        } else {
            None
        };
        // First epoch: untimed warm-up (exercises the full pipeline once).
        dl.begin_epoch(0);
        while dl.next_epoch_batch().unwrap().is_some() {}
        if let Some(p) = &planner {
            assert!(p.wait_idle(Duration::from_secs(30)));
        }
        // Invalidate everything through the gateway so the second epoch
        // starts cold for both configurations.
        let http = HttpClient::new(true);
        for s in &manifest.samples {
            let resp = http
                .request(
                    "POST",
                    &c.proxy_addr(),
                    &format!("/v1/invalidate?bucket=rb&obj={}", s.name),
                    &[],
                )
                .unwrap();
            assert_eq!(resp.status, 200);
        }
        // Second epoch, timed: fetch + compute per batch.
        let t0 = Instant::now();
        dl.begin_epoch(1);
        while dl.next_epoch_batch().unwrap().is_some() {
            std::thread::sleep(compute);
        }
        t0.elapsed()
    };

    // Wall-time comparison under injected latency is timing-sensitive:
    // the bounded retry-once guard absorbs a single CI scheduling hiccup,
    // while a real regression fails both attempts. Seed 7 is the loader
    // shuffle seed both runs share.
    retry_once("epoch_prefetch::on_beats_off", 7, || {
        let off = run(false);
        let on = run(true);
        if on >= off {
            return Err(format!(
                "prefetch ON epoch ({on:?}) must strictly beat OFF ({off:?}) \
                 under injected latency"
            ));
        }
        Ok(())
    });
}

/// (c) The memory invariant and coherence under prefetch: resident cache
/// bytes never exceed `cache_bytes` on any target at any batch boundary,
/// and a mid-epoch overwrite (PR 5 coherence) invalidates the prefetched
/// chunks — the loader serves the fresh bytes, and the dropped pins are
/// accounted as wasted prefetch.
#[test]
fn prefetch_respects_cache_capacity_and_overwrite_invalidates() {
    let gb = GetBatchConfig {
        // Deliberately tight: 8 chunks of 8 KiB per target, so one
        // 3-object batch (9 chunks) cannot even fit — the pin-aware
        // admission has to decline speculative chunks instead of
        // overshooting.
        chunk_bytes: 8 << 10,
        dt_buffer_bytes: 64 << 10,
        cache_bytes: 64 << 10,
        readahead_chunks: 1,
        prefetch_batches: 2,
        coherence_grace: Duration::ZERO, // every open revalidates: overwrite visibility is deterministic
        ..Default::default()
    }
    .sanitized();
    assert!(gb.prefetch_batches >= 1);

    let storage = fixtures::cluster(1);
    let manifest = stage(&storage, 12, 24 << 10); // 3 chunks per object, batches of 3 ⇒ 4 batches
    let c = serving(&storage.proxy_addr(), gb.clone());
    let client = Client::new(&c.proxy_addr());
    let planner = PrefetchPlanner::new(client.clone(), gb.prefetch_batches, 4);
    let mut dl = DataLoader::new(client.clone(), manifest.clone(), AccessMode::GetBatch, 3, 21);
    dl.attach_prefetch(Arc::clone(&planner));

    let check_capacity = |tag: &str| {
        for t in &c.targets {
            assert!(
                t.cache.resident_bytes() <= t.cache.capacity(),
                "{}: cache over capacity at {tag}: {} > {}",
                t.info.id,
                t.cache.resident_bytes(),
                t.cache.capacity()
            );
        }
    };

    dl.begin_epoch(0);
    // Batch 0: its demand read triggers prefetch of batches 1 and 2.
    let (b0, _) = dl.next_epoch_batch().unwrap().unwrap();
    assert_eq!(b0.len(), 3);
    assert!(planner.wait_idle(Duration::from_secs(30)));
    check_capacity("after batch 0 + prefetch");

    // Mid-epoch overwrite of an object in the *next* (already prefetched)
    // batch, written through a serving target: write-through to storage +
    // invalidation broadcast (PR 5).
    let victim = {
        let plan = dl.epoch_plan().unwrap();
        manifest.samples[plan.batch(1).unwrap()[0]].name.clone()
    };
    let fresh = payload(24 << 10, 0xF00D);
    let http = HttpClient::new(true);
    let resp = http
        .put(
            &c.target_addr(0),
            &getbatch::proto::wire::object_path("rb", &victim),
            &fresh,
        )
        .unwrap();
    assert_eq!(resp.status, 200);

    // Drain the rest of the epoch, holding the capacity oracle throughout,
    // and catch the overwritten object as it is served.
    let mut victim_bytes = None;
    while let Some((samples, _)) = dl.next_epoch_batch().unwrap() {
        for s in &samples {
            if s.name == victim {
                victim_bytes = Some(s.data.clone());
            }
        }
        assert!(planner.wait_idle(Duration::from_secs(30)));
        check_capacity("mid-epoch");
    }
    check_capacity("epoch end");

    let served = victim_bytes.expect("victim object was part of the epoch");
    assert_eq!(
        served, fresh,
        "overwritten object served fresh, never the prefetched stale bytes"
    );
    assert!(
        sum(&c, |t| t.cache.prefetch_wasted.get()) >= 1,
        "invalidated/declined prefetched chunks were accounted as wasted"
    );
    // The tight cache forced at least some speculative work to be dropped
    // or churned — and the pipeline still never overshot capacity.
    assert!(sum(&c, |t| t.cache.fills_prefetch.get()) > 0, "prefetch path exercised");
}
