//! Failure-path coverage (§2.4.2): missing data, injected disk faults, GFN
//! recovery, soft-error budgets, and late/duplicate frame handling.

use getbatch::batch::request::{BatchEntry, BatchRequest};
use getbatch::client::sdk::{Client, ClientError};
use getbatch::cluster::node::Cluster;
use getbatch::config::{ClusterConfig, GetBatchConfig};
use getbatch::metrics::GetBatchMetrics;
use getbatch::testutil::fixtures;

#[test]
fn many_missing_entries_within_budget_all_placeholders() {
    let c = fixtures::cluster(3);
    fixtures::stage_objects(&c, "b", 10, 256, 1);
    let client = Client::new(&c.proxy_addr());
    let mut entries = Vec::new();
    for i in 0..10 {
        entries.push(BatchEntry::obj("b", &format!("obj-{i:06}")));
        entries.push(BatchEntry::obj("b", &format!("ghost-{i}")));
    }
    let items = client
        .get_batch_collect(&BatchRequest::new(entries).continue_on_err(true))
        .unwrap();
    assert_eq!(items.len(), 20);
    for (i, it) in items.iter().enumerate() {
        assert_eq!(it.is_missing(), i % 2 == 1, "position {i}");
    }
}

#[test]
fn soft_error_budget_aborts_request() {
    let cfg = ClusterConfig {
        targets: 2,
        getbatch: GetBatchConfig { max_soft_errs: 3, ..Default::default() },
        ..Default::default()
    };
    let c = Cluster::start(cfg).unwrap();
    fixtures::stage_objects(&c, "b", 1, 64, 2);
    let client = Client::new(&c.proxy_addr());
    let entries: Vec<BatchEntry> =
        (0..8).map(|i| BatchEntry::obj("b", &format!("ghost-{i}"))).collect();
    // budget 3 < 8 missing → hard failure despite continue_on_err
    let err = client
        .get_batch_collect(&BatchRequest::new(entries).continue_on_err(true).streaming(false))
        .unwrap_err();
    match err {
        ClientError::Status { status, msg } => {
            assert_eq!(status, 500);
            assert!(msg.contains("soft-error budget"), "{msg}");
        }
        other => panic!("{other:?}"),
    }
    let hard: f64 = c
        .targets
        .iter()
        .map(|t| t.metrics.hard_failures.get() as f64)
        .sum();
    assert_eq!(hard, 1.0);
}

#[test]
fn injected_read_faults_recovered_or_surfaced() {
    let c = fixtures::cluster(3);
    let names = fixtures::stage_objects(&c, "b", 30, 512, 3);
    // inject 100% read failure on one target: its objects fail locally,
    // GFN tries neighbors (who don't own replicas → also fail) → with coer
    // the entries become placeholders, others succeed.
    c.targets[0].store.set_fault_rate(1.0);
    let client = Client::new(&c.proxy_addr());
    let entries: Vec<BatchEntry> = names.iter().map(|n| BatchEntry::obj("b", n)).collect();
    let items = client
        .get_batch_collect(&BatchRequest::new(entries).continue_on_err(true))
        .unwrap();
    assert_eq!(items.len(), 30);
    let missing = items.iter().filter(|i| i.is_missing()).count();
    assert!(missing > 0, "t0-owned objects should fail");
    assert!(missing < 30, "other targets' objects should succeed");
    // recovery was attempted for recoverable read failures
    let attempts: u64 = c.targets.iter().map(|t| t.metrics.recovery_attempts.get()).sum();
    assert!(attempts > 0, "GFN should have been attempted");
}

#[test]
fn gfn_recovery_succeeds_when_neighbor_has_object() {
    // Place a copy of the object on a *non-owner* target directly, then
    // break the owner: GFN must find the neighbor copy.
    let c = fixtures::cluster(3);
    let client = Client::new(&c.proxy_addr());
    let key = "replicated-obj";
    c.put_direct("b", key, b"precious").unwrap();
    let owner = getbatch::cluster::placement::owner(&c.smap, &format!("b/{key}"));
    // copy to every other node (n-way mirror)
    for (i, t) in c.targets.iter().enumerate() {
        if i != owner {
            t.store.put("b", key, b"precious").unwrap();
        }
    }
    c.targets[owner].store.set_fault_rate(1.0);
    let items = client
        .get_batch_collect(
            &BatchRequest::new(vec![BatchEntry::obj("b", key)]).continue_on_err(true),
        )
        .unwrap();
    assert_eq!(items.len(), 1);
    assert_eq!(items[0].data(), Some(&b"precious"[..]), "recovered from neighbor");
}

#[test]
fn late_frames_for_finished_requests_are_dropped() {
    let c = fixtures::cluster(2);
    fixtures::stage_objects(&c, "b", 4, 128, 4);
    let client = Client::new(&c.proxy_addr());
    let entries: Vec<BatchEntry> =
        (0..4).map(|i| BatchEntry::obj("b", &format!("obj-{i:06}"))).collect();
    client.get_batch_collect(&BatchRequest::new(entries)).unwrap();
    // send a frame for a long-gone request id straight into each registry
    for t in &c.targets {
        t.registry.dispatch(getbatch::proto::frame::Frame::data(424242, 0, vec![1]));
    }
    // cluster still healthy
    let items = client
        .get_batch_collect(&BatchRequest::new(vec![BatchEntry::obj("b", "obj-000000")]))
        .unwrap();
    assert_eq!(items.len(), 1);
}

#[test]
fn per_request_state_released_after_completion_and_abort() {
    let c = fixtures::cluster(2);
    fixtures::stage_objects(&c, "b", 2, 64, 5);
    let client = Client::new(&c.proxy_addr());
    // success
    client
        .get_batch_collect(&BatchRequest::new(vec![BatchEntry::obj("b", "obj-000000")]))
        .unwrap();
    // abort (missing, no coer, buffered so the error is clean)
    let _ = client.get_batch_collect(
        &BatchRequest::new(vec![BatchEntry::obj("b", "nope")]).streaming(false),
    );
    std::thread::sleep(std::time::Duration::from_millis(100));
    for t in &c.targets {
        assert_eq!(t.registry.inflight(), 0, "state leaked on {}", t.info.id);
    }
}

#[test]
fn metrics_count_soft_errors_and_rejections() {
    let c = fixtures::cluster(2);
    fixtures::stage_objects(&c, "b", 1, 64, 6);
    let client = Client::new(&c.proxy_addr());
    let _ = client.get_batch_collect(
        &BatchRequest::new(vec![
            BatchEntry::obj("b", "obj-000000"),
            BatchEntry::obj("b", "ghost"),
        ])
        .continue_on_err(true),
    );
    let soft: f64 = c
        .targets
        .iter()
        .map(|t| {
            GetBatchMetrics::parse(&t.metrics.render(&t.info.id))["ais_getbatch_soft_errors_total"]
        })
        .sum();
    assert!(soft >= 1.0);
}
