//! The endpoint-failover scenario family: multi-endpoint remote buckets
//! under endpoint death — mid-stream resume on a healthy endpoint, CRC
//! fail-closed on divergent replicas, a live GetBatch surviving an endpoint
//! kill with zero client-visible errors, the health gauge flipping
//! unhealthy → healthy when an endpoint returns, and the cache staying
//! byte-identical over a failing-over backend.

use std::collections::HashMap;
use std::io::{self, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use getbatch::batch::request::{BatchEntry, BatchRequest};
use getbatch::client::sdk::Client;
use getbatch::config::{ClusterConfig, GetBatchConfig};
use getbatch::metrics::GetBatchMetrics;
use getbatch::proto::http::{
    range_unsatisfiable, resolve_range, Handler, HttpServer, RangeSpec, Request, Response,
};
use getbatch::proto::wire;
use getbatch::store::{Backend, CachedBackend, ChunkCache, RemoteBackend, StoreError};
use getbatch::testutil::fixtures;
use getbatch::util::crc32;
use getbatch::util::rng::Rng;

fn payload(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let mut buf = vec![0u8; n];
    rng.fill_bytes(&mut buf);
    buf
}

/// A controllable storage endpoint speaking the internal object API over an
/// in-memory object map (keys `bucket/obj`):
/// - `dead` flips every response (including `/v1/health`) to 500;
/// - `die_after` makes ranged GETs deliver that many bytes, then abort the
///   connection mid-chunked-stream (the endpoint-death-mid-read shape);
/// - `crc_override` advertises a chosen sidecar instead of the payload's
///   real CRC (models an endpoint serving divergent bytes).
struct StubEndpoint {
    addr: String,
    dead: Arc<AtomicBool>,
    _srv: HttpServer,
}

fn stub_endpoint(
    objects: HashMap<String, Vec<u8>>,
    die_after: Option<usize>,
    crc_override: Option<u32>,
) -> StubEndpoint {
    let objects = Arc::new(objects);
    let dead = Arc::new(AtomicBool::new(false));
    let dead2 = Arc::clone(&dead);
    let handler: Handler = Arc::new(move |req: Request| {
        if dead2.load(Ordering::Relaxed) {
            return Response::text(500, "endpoint down");
        }
        if req.path == wire::paths::HEALTH {
            return Response::ok(b"ok".to_vec());
        }
        let (bucket, obj) = match wire::parse_object_path(&req.path) {
            Some(x) => x,
            None => return Response::status(404),
        };
        if req.method != "GET" {
            return Response::status(400);
        }
        let data = match objects.get(&format!("{bucket}/{obj}")) {
            Some(d) => d.clone(),
            None => return Response::status(404),
        };
        let crc = crc_override.unwrap_or_else(|| crc32::hash(&data));
        let len = data.len() as u64;
        let resp = match resolve_range(req.header("range"), len) {
            RangeSpec::Whole => Response::ok(data),
            RangeSpec::Slice { start, end } => {
                let slice = data[start as usize..end as usize].to_vec();
                match die_after {
                    Some(k) if slice.len() > k => {
                        let partial = slice[..k].to_vec();
                        Response::stream(move |w| {
                            w.write_all(&partial)?;
                            w.flush()?;
                            Err(io::Error::new(io::ErrorKind::Other, "injected endpoint death"))
                        })
                        .into_partial(start, end, len)
                    }
                    _ => Response::ok(slice).into_partial(start, end, len),
                }
            }
            RangeSpec::Unsatisfiable => range_unsatisfiable(len),
        };
        resp.with_header(wire::HDR_OBJ_CRC, &format!("{crc:08x}"))
    });
    let srv = HttpServer::serve(handler, 4, "stub-ep").unwrap();
    StubEndpoint { addr: srv.addr.to_string(), dead, _srv: srv }
}

#[test]
fn midstream_endpoint_death_resumes_on_healthy_endpoint() {
    // Endpoint A aborts every multi-chunk ranged read after 8 KiB;
    // endpoint B serves the same object intact. Reads that start on A must
    // resume at the current offset on B — byte-identical, no error.
    let data = payload(100 << 10, 42);
    let mut objects = HashMap::new();
    objects.insert("b/o".to_string(), data.clone());
    let a = stub_endpoint(objects.clone(), Some(8 << 10), None);
    let b = stub_endpoint(objects, None, None);

    let metrics = GetBatchMetrics::new();
    let remote = RemoteBackend::multi(
        &[&a.addr, &b.addr],
        10, // keep A selectable so the dying stream is exercised repeatedly
        Duration::from_millis(100),
        Some(Arc::clone(&metrics)),
    );
    let mut saw_failover = false;
    for i in 0..4 {
        // A successful read consumes an even number of round-robin picks
        // (probe + stream open); the extra probe shifts parity so the
        // stream open reaches the dying endpoint within two iterations.
        let _ = remote.size("b", "o").unwrap();
        let got = remote.open_entry("b", "o").unwrap().read_all().unwrap();
        assert_eq!(got, data, "read {i} byte-identical despite endpoint death");
        if metrics.remote_failovers.get() > 0 {
            saw_failover = true;
            break;
        }
    }
    assert!(saw_failover, "round-robin reached the dying endpoint");
    assert!(metrics.remote_fetches.get() > 0);
}

#[test]
fn repeated_death_opens_circuit_and_b_serves_alone() {
    let data = payload(64 << 10, 7);
    let mut objects = HashMap::new();
    objects.insert("b/o".to_string(), data.clone());
    let a = stub_endpoint(objects.clone(), Some(4 << 10), None);
    let b = stub_endpoint(objects, None, None);

    let metrics = GetBatchMetrics::new();
    let remote = RemoteBackend::multi(
        &[&a.addr, &b.addr],
        1, // first mid-stream death opens A's circuit
        Duration::from_secs(60),
        Some(Arc::clone(&metrics)),
    );
    for _ in 0..6 {
        let _ = remote.size("b", "o").unwrap(); // parity shift (see above)
        assert_eq!(remote.open_entry("b", "o").unwrap().read_all().unwrap(), data);
    }
    // Once A died mid-stream its circuit opened (limit 1, long probe
    // window) and every later read came off B without further failovers.
    assert!(!remote.endpoints().is_healthy(&a.addr), "A's circuit open");
    assert!(remote.endpoints().is_healthy(&b.addr));
    assert_eq!(metrics.endpoints_unhealthy.get(), 1);
}

#[test]
fn failover_crc_mismatch_fails_closed() {
    // Endpoint A serves *divergent* bytes (same length) and dies
    // mid-stream; endpoint B serves the true object. Both advertise the
    // true object's sidecar CRC. A read stitched A-prefix + B-suffix must
    // fail the EOF CRC check instead of returning silently corrupt bytes.
    let good = payload(64 << 10, 1);
    let bad = payload(64 << 10, 2);
    let want_crc = crc32::hash(&good);
    let mut a_objects = HashMap::new();
    a_objects.insert("b/o".to_string(), bad);
    let mut b_objects = HashMap::new();
    b_objects.insert("b/o".to_string(), good.clone());
    let a = stub_endpoint(a_objects, Some(4 << 10), Some(want_crc));
    let b = stub_endpoint(b_objects, None, None);

    let remote = RemoteBackend::multi(
        &[&a.addr, &b.addr],
        10,
        Duration::from_millis(100),
        None,
    );
    let mut saw_mismatch = false;
    for _ in 0..6 {
        let _ = remote.size("b", "o").unwrap(); // parity shift (see above)
        match remote.open_entry("b", "o").unwrap().read_all() {
            // Stream served wholly by B: fine, and must be the true bytes.
            Ok(got) => assert_eq!(got, good),
            // Stream stitched across A and B: must fail closed.
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.contains("CRC mismatch"), "unexpected error: {msg}");
                saw_mismatch = true;
                break;
            }
        }
    }
    assert!(saw_mismatch, "a stitched read must trip the CRC check");
}

#[test]
fn getbatch_survives_endpoint_kill_with_zero_client_errors() {
    // The acceptance scenario: a 2-endpoint remote bucket (two storage
    // clusters holding identical data), one endpoint killed between
    // batches. The batch over the surviving endpoint completes
    // byte-identical with zero client-visible errors and a positive
    // failover count.
    let s1 = fixtures::cluster(1);
    let s2 = fixtures::cluster(1);
    let mut staged: Vec<(String, Vec<u8>)> = Vec::new();
    for i in 0..8 {
        let name = format!("obj-{i:03}");
        let data = payload(40 << 10, 900 + i);
        s1.put_direct("rb", &name, &data).unwrap();
        s2.put_direct("rb", &name, &data).unwrap();
        staged.push((name, data));
    }

    let c = getbatch::Cluster::start(ClusterConfig {
        targets: 2,
        http_workers: 4,
        getbatch: GetBatchConfig {
            chunk_bytes: 16 << 10,
            dt_buffer_bytes: 64 << 10,
            endpoint_failure_limit: 1,
            endpoint_probe: Duration::from_millis(100),
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    c.route_remote_bucket("rb", &[&s1.proxy_addr(), &s2.proxy_addr()], false);
    let client = Client::new(&c.proxy_addr());
    let entries: Vec<BatchEntry> = staged.iter().map(|(n, _)| BatchEntry::obj("rb", n)).collect();

    // Both endpoints alive: baseline batch.
    let items = client.get_batch_collect(&BatchRequest::new(entries.clone())).unwrap();
    for (item, (_, data)) in items.iter().zip(&staged) {
        assert_eq!(item.data().unwrap(), &data[..]);
    }

    // Kill endpoint 1; the batch must still complete byte-identically with
    // no placeholders and no client-visible error.
    drop(s1);
    let items = client.get_batch_collect(&BatchRequest::new(entries)).unwrap();
    assert_eq!(items.len(), staged.len());
    for (item, (name, data)) in items.iter().zip(&staged) {
        assert!(!item.is_missing(), "{name} must not degrade to a placeholder");
        assert_eq!(item.data().unwrap(), &data[..], "{name} byte-identical after kill");
    }
    let failovers: u64 = c.targets.iter().map(|t| t.metrics.remote_failovers.get()).sum();
    assert!(failovers > 0, "dead endpoint forced failovers");
    let unhealthy: i64 = c.targets.iter().map(|t| t.metrics.endpoints_unhealthy.get()).sum();
    assert!(unhealthy > 0, "dead endpoint marked unhealthy somewhere");
    let hard: u64 = c.targets.iter().map(|t| t.metrics.hard_failures.get()).sum();
    assert_eq!(hard, 0, "no aborted requests");
}

#[test]
fn health_gauge_flips_when_endpoint_returns() {
    // A revivable stub endpoint + a real storage cluster serving the same
    // objects. Killing the stub marks it unhealthy on the serving targets;
    // once it returns, traffic-triggered /v1/health probes flip its gauge
    // back to healthy.
    let storage = fixtures::cluster(1);
    let mut objects = HashMap::new();
    let mut staged: Vec<(String, Vec<u8>)> = Vec::new();
    for i in 0..6 {
        let name = format!("obj-{i:03}");
        let data = payload(20 << 10, 300 + i);
        storage.put_direct("rb", &name, &data).unwrap();
        objects.insert(format!("rb/{name}"), data.clone());
        staged.push((name, data));
    }
    let stub = stub_endpoint(objects, None, None);

    let c = getbatch::Cluster::start(ClusterConfig {
        targets: 2,
        http_workers: 4,
        getbatch: GetBatchConfig {
            chunk_bytes: 16 << 10,
            dt_buffer_bytes: 64 << 10,
            endpoint_failure_limit: 1,
            endpoint_probe: Duration::from_millis(50),
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    c.route_remote_bucket("rb", &[&stub.addr, &storage.proxy_addr()], false);
    let client = Client::new(&c.proxy_addr());
    let entries: Vec<BatchEntry> = staged.iter().map(|(n, _)| BatchEntry::obj("rb", n)).collect();
    let run = |tag: &str| {
        let items = client.get_batch_collect(&BatchRequest::new(entries.clone())).unwrap();
        for (item, (name, data)) in items.iter().zip(&staged) {
            assert_eq!(item.data().unwrap(), &data[..], "{tag}: {name}");
        }
    };
    let unhealthy = |c: &getbatch::Cluster| -> i64 {
        c.targets.iter().map(|t| t.metrics.endpoints_unhealthy.get()).sum()
    };

    run("both alive");
    assert_eq!(unhealthy(&c), 0);

    // Stub down: batches keep completing; the stub goes unhealthy.
    stub.dead.store(true, Ordering::Relaxed);
    let mut went_unhealthy = false;
    for _ in 0..10 {
        run("stub dead");
        if unhealthy(&c) > 0 {
            went_unhealthy = true;
            break;
        }
    }
    assert!(went_unhealthy, "dead stub marked unhealthy");

    // Stub back: traffic-triggered probes close the circuit again.
    stub.dead.store(false, Ordering::Relaxed);
    let mut recovered = false;
    for _ in 0..100 {
        run("stub revived");
        if unhealthy(&c) == 0 {
            recovered = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(recovered, "health gauge flipped back after the endpoint returned");
    let probes: u64 = c.targets.iter().map(|t| t.metrics.endpoint_probes.get()).sum();
    assert!(probes > 0, "active probes fired");
}

#[test]
fn all_endpoints_down_surfaces_io_and_coer_placeholder() {
    // Backend level: the error is a *typed* Io — never NotFound (a dead
    // endpoint is not a clean miss) and never a hang.
    let dead = RemoteBackend::multi(
        &["127.0.0.1:1", "127.0.0.1:2"],
        3,
        Duration::from_millis(50),
        None,
    );
    assert!(matches!(dead.open_entry("b", "o"), Err(StoreError::Io(_))));
    assert!(matches!(dead.size("b", "o"), Err(StoreError::Io(_))));

    // Cluster level: a bucket routed to two dead endpoints degrades to
    // soft errors / placeholders under continue-on-error, never a hang.
    let c = getbatch::Cluster::start(ClusterConfig {
        targets: 2,
        http_workers: 4,
        getbatch: GetBatchConfig {
            sender_wait: Duration::from_millis(1500),
            gfn_attempts: 1,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    c.route_remote_bucket("rb", &["127.0.0.1:1", "127.0.0.1:2"], false);
    let client = Client::new(&c.proxy_addr());
    let req = BatchRequest::new(vec![BatchEntry::obj("rb", "gone")]).continue_on_err(true);
    let items = client.get_batch_collect(&req).unwrap();
    assert_eq!(items.len(), 1);
    assert!(items[0].is_missing(), "all-endpoints-down surfaced as a placeholder");
    // The degradation is visible in the soft-error metric family: the read
    // failure was tolerated (soft), and recovery was attempted and failed
    // (no neighbor holds a remote-bucket replica).
    let soft: u64 = c.targets.iter().map(|t| t.metrics.soft_errors.get()).sum();
    assert!(soft > 0, "tolerated failure counted as a soft error");
    let attempts: u64 = c.targets.iter().map(|t| t.metrics.recovery_attempts.get()).sum();
    let failures: u64 = c.targets.iter().map(|t| t.metrics.recovery_failures.get()).sum();
    assert!(attempts > 0, "GFN recovery was attempted");
    assert!(failures > 0, "recovery cannot succeed with every endpoint down");
    let hard_before: u64 = c.targets.iter().map(|t| t.metrics.hard_failures.get()).sum();
    assert_eq!(hard_before, 0, "coer run aborted nothing");

    // Without continue-on-error the same failure is a hard abort: the
    // streaming response is truncated and the client surfaces a typed I/O
    // error — not a placeholder item.
    let strict = BatchRequest::new(vec![BatchEntry::obj("rb", "gone")]);
    match client.get_batch_collect(&strict) {
        Err(getbatch::client::sdk::ClientError::Tar(getbatch::tar::TarError::Io(_)))
        | Err(getbatch::client::sdk::ClientError::Io(_)) => {}
        other => panic!("expected a typed Io error, got {other:?}"),
    }
    let hard: u64 = c.targets.iter().map(|t| t.metrics.hard_failures.get()).sum();
    assert!(hard > 0, "non-coer abort counted as a hard failure");
}

#[test]
fn cache_over_failover_backend_stays_byte_identical() {
    // The read-through chunk cache composes over a failing-over remote
    // backend: fills whose inner ranged read dies mid-stream still insert
    // the true bytes, cold and warm reads are byte-identical, and warm
    // reads come from cache.
    let data = payload(96 << 10, 5);
    let mut objects = HashMap::new();
    objects.insert("b/o".to_string(), data.clone());
    let a = stub_endpoint(objects.clone(), Some(6 << 10), None);
    let b = stub_endpoint(objects, None, None);

    let metrics = GetBatchMetrics::new();
    let remote: Arc<dyn Backend> = Arc::new(RemoteBackend::multi(
        &[&a.addr, &b.addr],
        10,
        Duration::from_millis(100),
        Some(Arc::clone(&metrics)),
    ));
    let cache = Arc::new(ChunkCache::new(1 << 20, 16 << 10, None));
    // Long coherence grace: this test exercises failover transparency, not
    // revalidation — warm opens must stay metadata-probe-free.
    let cached = CachedBackend::new(remote, Arc::clone(&cache), 2, Duration::from_secs(3600));

    let mut saw_failover = false;
    for i in 0..4 {
        cache.invalidate_object("b", "o");
        let cold = cached.open_entry("b", "o").unwrap().read_all().unwrap();
        assert_eq!(cold, data, "cold fill {i} byte-identical");
        let warm = cached.open_entry("b", "o").unwrap().read_all().unwrap();
        assert_eq!(warm, data, "warm read {i} byte-identical");
        if metrics.remote_failovers.get() > 0 {
            saw_failover = true;
            break;
        }
    }
    assert!(saw_failover, "a fill exercised the failover path");
    assert!(cache.hits.get() > 0, "warm reads served from cache");
}
