//! Cross-module integration: TAR ⇄ store ⇄ HTTP ⇄ batch reader without a
//! full cluster — the seams between substrates.

use std::io::Write;
use std::sync::Arc;
use std::time::Duration;

use getbatch::batch::reader::BatchReader;
use getbatch::batch::request::{BatchEntry, BatchRequest};
use getbatch::proto::http::{Handler, HttpClient, HttpServer, Request, Response};
use getbatch::proto::frame::{read_frame, write_frame, Frame, FrameType};
use getbatch::store::{ObjectStore, ShardIndexCache};
use getbatch::tar::{self, Entry, TarWriter};
use getbatch::util::rng::Rng;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir().join(format!("gbint-{}-{}", std::process::id(), name));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

#[test]
fn tar_shard_through_store_and_extraction() {
    let dir = tmpdir("shard");
    let store = ObjectStore::open(&dir, 3).unwrap();
    let cache = ShardIndexCache::new(8);
    let mut rng = Rng::new(42);
    let entries: Vec<Entry> = (0..32)
        .map(|i| {
            let mut data = vec![0u8; 100 + (i * 37) % 900];
            rng.fill_bytes(&mut data);
            Entry { name: format!("member-{i:03}"), data }
        })
        .collect();
    store.put("b", "s.tar", &tar::write_archive(&entries).unwrap()).unwrap();
    // random-order extraction matches original payloads
    let mut order: Vec<usize> = (0..32).collect();
    rng.shuffle(&mut order);
    for i in order {
        let got =
            cache.extract(&store, "b", "s.tar", &format!("member-{i:03}")).unwrap().read_all().unwrap();
        assert_eq!(got, entries[i].data);
    }
    std::fs::remove_dir_all(dir).unwrap();
}

#[test]
fn http_serves_tar_stream_readable_by_batch_reader() {
    // An HTTP endpoint that streams a TAR with a placeholder inside —
    // exactly what a DT response looks like — must round-trip through the
    // client and BatchReader.
    let handler: Handler = Arc::new(|_req: Request| {
        Response::stream(|w| {
            let mut tw = TarWriter::new(&mut *w);
            tw.append("e0", &[1; 700]).map_err(std::io::Error::other)?;
            tw.append_missing("e1").map_err(std::io::Error::other)?;
            tw.append("e2", &[3; 12]).map_err(std::io::Error::other)?;
            tw.finish().map_err(std::io::Error::other)?;
            w.flush()
        })
    });
    let srv = HttpServer::serve(handler, 2, "tarstream").unwrap();
    let client = HttpClient::new(true);
    let resp = client.get(&srv.addr.to_string(), "/stream").unwrap();
    assert_eq!(resp.status, 200);
    let items = BatchReader::new(resp.body).collect_all().unwrap();
    assert_eq!(items.len(), 3);
    assert!(!items[0].is_missing());
    assert!(items[1].is_missing());
    assert_eq!(items[2].data().unwrap(), &[3; 12]);
}

#[test]
fn frame_protocol_over_real_sockets_preserves_payloads() {
    use std::net::{TcpListener, TcpStream};
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut got = Vec::new();
        while let Some(f) = read_frame(&mut s).unwrap() {
            got.push(f);
        }
        got
    });
    let mut s = TcpStream::connect(addr).unwrap();
    let mut rng = Rng::new(7);
    let mut sent = Vec::new();
    for i in 0..50u32 {
        let mut payload = vec![0u8; (i as usize * 131) % 4096];
        rng.fill_bytes(&mut payload);
        let f = Frame::data(99, i, payload);
        write_frame(&mut s, &f).unwrap();
        sent.push(f);
    }
    write_frame(&mut s, &Frame::sender_done(99, 50)).unwrap();
    drop(s);
    let got = server.join().unwrap();
    assert_eq!(got.len(), 51);
    assert_eq!(&got[..50], &sent[..]);
    assert_eq!(got[50].ftype, FrameType::SenderDone);
}

#[test]
fn batch_request_wire_roundtrip_through_http() {
    // GET with a JSON body (the §2.2 wire pattern) over a real socket.
    let handler: Handler = Arc::new(|req: Request| {
        let parsed = BatchRequest::from_body(&req.body).unwrap();
        Response::ok(parsed.entries.len().to_string().into_bytes())
    });
    let srv = HttpServer::serve(handler, 2, "wire").unwrap();
    let client = HttpClient::new(true);
    let req = BatchRequest::new(
        (0..257).map(|i| BatchEntry::obj("bucket", &format!("o{i}"))).collect(),
    );
    let resp = client.request("GET", &srv.addr.to_string(), "/v1/batch", &req.to_body()).unwrap();
    assert_eq!(resp.into_bytes().unwrap(), b"257");
}

#[test]
fn server_survives_abusive_clients() {
    let handler: Handler = Arc::new(|_req| Response::ok(b"fine".to_vec()));
    let srv = HttpServer::serve(handler, 2, "abuse").unwrap();
    let addr = srv.addr.to_string();
    // garbage request line
    {
        use std::io::Read;
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.write_all(b"NOT-HTTP\r\n\r\n").unwrap();
        let mut buf = [0u8; 16];
        let _ = s.read(&mut buf); // server just drops the conn
    }
    // connection opened and abandoned
    {
        let _s = std::net::TcpStream::connect(&addr).unwrap();
    }
    // server still serves real clients
    let client = HttpClient::new(false);
    std::thread::sleep(Duration::from_millis(50));
    let resp = client.get(&addr, "/x").unwrap();
    assert_eq!(resp.into_bytes().unwrap(), b"fine");
}
