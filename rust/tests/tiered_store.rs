//! The tiered multi-backend store, end to end: remote HTTP backend reads
//! (single node and proxy-fronted cluster), the read-through chunk cache
//! under a live GetBatch, fault surfacing when a remote endpoint dies, and
//! GFN recovery across a remote-backed bucket.

use std::time::Duration;

use getbatch::batch::request::{BatchEntry, BatchRequest};
use getbatch::client::sdk::Client;
use getbatch::cluster::placement;
use getbatch::config::{ClusterConfig, GetBatchConfig};
use getbatch::store::{Backend, RemoteBackend, StoreError};
use getbatch::testutil::fixtures;
use getbatch::util::rng::Rng;

fn payload(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let mut buf = vec![0u8; n];
    rng.fill_bytes(&mut buf);
    buf
}

#[test]
fn remote_backend_full_object_lifecycle() {
    // Storage cluster fronted by its proxy; the backend drives object
    // CRUD + ranged reads against it over plain HTTP.
    let storage = fixtures::cluster(2);
    let remote = RemoteBackend::new(&storage.proxy_addr(), None);

    let data = payload(100 << 10, 11);
    remote.put("rb", "obj", &data).unwrap();
    assert!(remote.exists("rb", "obj"));
    assert_eq!(remote.size("rb", "obj").unwrap(), data.len() as u64);
    assert_eq!(
        remote.content_crc("rb", "obj"),
        Some(getbatch::util::crc32::hash(&data)),
        "PUT-time sidecar readable through the remote tier"
    );

    // Whole-object streaming read, chunk by chunk.
    let mut r = remote.open_entry("rb", "obj").unwrap();
    assert_eq!(r.len(), data.len() as u64);
    let mut rebuilt = Vec::new();
    loop {
        let c = r.read_chunk(16 << 10).unwrap();
        if c.is_empty() {
            break;
        }
        rebuilt.extend_from_slice(&c);
    }
    assert_eq!(rebuilt, data, "remote read byte-identical");

    // Ranged read + seek.
    let mut r = remote.open_entry_range("rb", "obj", 1000, 5000).unwrap();
    assert_eq!(r.read_chunk(5000).unwrap(), &data[1000..6000]);
    let mut r = remote.open_entry("rb", "obj").unwrap();
    r.seek_to(90 << 10).unwrap();
    assert_eq!(r.read_all().unwrap(), &data[90 << 10..]);
    // span past EOF rejected at open
    assert!(remote.open_entry_range("rb", "obj", (99 << 10) as u64, 4 << 10).is_err());

    // Listing fans out through the proxy across all storage targets.
    remote.put("rb", "dir/second", b"x").unwrap();
    assert_eq!(remote.list("rb").unwrap(), vec!["dir/second", "obj"]);

    remote.delete("rb", "dir/second").unwrap();
    assert_eq!(remote.list("rb").unwrap(), vec!["obj"]);
    assert!(matches!(remote.delete("rb", "dir/second"), Err(StoreError::NotFound(_))));
    assert!(matches!(remote.open_entry("rb", "missing"), Err(StoreError::NotFound(_))));
}

#[test]
fn remote_backend_zero_length_object() {
    // Regression: the 1-byte metadata probe asks for `bytes=0-0`, which a
    // 0-byte object cannot satisfy — the probe must resolve the empty /
    // unsatisfiable range response to `size == 0`, not an error.
    let storage = fixtures::cluster(1);
    let remote = RemoteBackend::new(&storage.proxy_addr(), None);
    remote.put("rb", "empty", b"").unwrap();
    assert!(remote.exists("rb", "empty"));
    assert_eq!(remote.size("rb", "empty").unwrap(), 0);
    let r = remote.open_entry("rb", "empty").unwrap();
    assert!(r.is_empty());
    assert_eq!(r.read_all().unwrap(), b"");
    // ...and through a GetBatch over the remote tier.
    let c = serving_cluster(&storage.proxy_addr(), false);
    let client = Client::new(&c.proxy_addr());
    let items = client
        .get_batch_collect(&BatchRequest::new(vec![BatchEntry::obj("rb", "empty")]))
        .unwrap();
    assert_eq!(items.len(), 1);
    assert_eq!(items[0].data().unwrap(), b"");
}

#[test]
fn remote_backend_node_down_surfaces_io() {
    // Nothing listens on port 1: every call must surface an I/O error (not
    // a clean NotFound, and never a hang or panic).
    let dead = RemoteBackend::new("127.0.0.1:1", None);
    assert!(matches!(dead.open_entry("b", "o"), Err(StoreError::Io(_))));
    assert!(matches!(dead.size("b", "o"), Err(StoreError::Io(_))));
    assert!(matches!(dead.list("b"), Err(StoreError::Io(_))));
    assert!(!dead.exists("b", "o"));
    assert_eq!(dead.content_crc("b", "o"), None);
}

/// Serving cluster with a small enforced budget + cache, its bucket `rb`
/// routed to the storage cluster's proxy.
fn serving_cluster(storage_addr: &str, cached: bool) -> getbatch::Cluster {
    let c = getbatch::Cluster::start(ClusterConfig {
        targets: 3,
        http_workers: 4,
        getbatch: GetBatchConfig {
            chunk_bytes: 16 << 10,
            dt_buffer_bytes: 64 << 10,
            cache_bytes: 4 << 20,
            readahead_chunks: 2,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    c.route_remote_bucket("rb", &[storage_addr], cached);
    c
}

#[test]
fn getbatch_through_remote_bucket_with_cache() {
    let storage = fixtures::cluster(2);
    let mut staged: Vec<(String, Vec<u8>)> = Vec::new();
    for i in 0..10 {
        let name = format!("obj-{i:03}");
        // Multi-chunk objects (20 KiB > 16 KiB chunks) exercise chunked
        // remote reads + read-ahead.
        let data = payload(20 << 10, 100 + i);
        storage.put_direct("rb", &name, &data).unwrap();
        staged.push((name, data));
    }

    let c = serving_cluster(&storage.proxy_addr(), true);
    let client = Client::new(&c.proxy_addr());
    let entries: Vec<BatchEntry> =
        staged.iter().map(|(n, _)| BatchEntry::obj("rb", n)).collect();

    // Cold run: every byte comes over the remote tier.
    let items = client.get_batch_collect(&BatchRequest::new(entries.clone())).unwrap();
    assert_eq!(items.len(), staged.len());
    for (item, (name, data)) in items.iter().zip(&staged) {
        assert_eq!(item.name(), name.as_str());
        assert_eq!(item.data().unwrap(), &data[..], "cold run byte-identical");
    }
    let fetches: u64 = c.targets.iter().map(|t| t.metrics.remote_fetches.get()).sum();
    assert!(fetches > 0, "cold run hit the remote backend");

    // Warm run: the chunk caches serve hits; bytes stay identical.
    let items = client.get_batch_collect(&BatchRequest::new(entries)).unwrap();
    for (item, (_, data)) in items.iter().zip(&staged) {
        assert_eq!(item.data().unwrap(), &data[..], "warm run byte-identical");
    }
    let hits: u64 = c.targets.iter().map(|t| t.metrics.cache_hits.get()).sum();
    assert!(hits > 0, "second run served cache hits");

    // Peak resident bytes respect the enforced DT budget even with
    // read-ahead filling the caches.
    for t in &c.targets {
        assert!(
            t.budget.peak() <= t.budget.budget(),
            "{}: peak {} exceeded budget {}",
            t.info.id,
            t.budget.peak(),
            t.budget.budget()
        );
        assert!(
            t.cache.resident_bytes() <= t.cache.capacity(),
            "{}: cache over capacity",
            t.info.id
        );
    }
}

#[test]
fn shard_members_extracted_through_remote_bucket() {
    let storage = fixtures::cluster(1);
    let entries: Vec<getbatch::tar::Entry> = (0..6)
        .map(|i| getbatch::tar::Entry { name: format!("u{i}.wav"), data: payload(3000, 500 + i) })
        .collect();
    let shard = getbatch::tar::write_archive(&entries).unwrap();
    storage.put_direct("rb", "s-0.tar", &shard).unwrap();

    let c = serving_cluster(&storage.proxy_addr(), true);
    let client = Client::new(&c.proxy_addr());
    let req = BatchRequest::new(vec![
        BatchEntry::member("rb", "s-0.tar", "u4.wav"),
        BatchEntry::member("rb", "s-0.tar", "u1.wav"),
    ]);
    let items = client.get_batch_collect(&req).unwrap();
    assert_eq!(items[0].name(), "s-0.tar/u4.wav");
    assert_eq!(items[0].data().unwrap(), &entries[4].data[..]);
    assert_eq!(items[1].data().unwrap(), &entries[1].data[..]);
}

#[test]
fn dead_remote_surfaces_as_placeholders_under_coer() {
    // All targets front `rb` from an endpoint nobody listens on: the read
    // failures surface as soft errors and, under continue-on-error, the
    // batch completes with placeholders instead of hanging or crashing.
    let c = getbatch::Cluster::start(ClusterConfig {
        targets: 2,
        http_workers: 4,
        getbatch: GetBatchConfig {
            sender_wait: Duration::from_millis(1500),
            gfn_attempts: 1,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    c.route_remote_bucket("rb", &["127.0.0.1:1"], false);
    let client = Client::new(&c.proxy_addr());
    let req = BatchRequest::new(vec![BatchEntry::obj("rb", "gone")]).continue_on_err(true);
    let items = client.get_batch_collect(&req).unwrap();
    assert_eq!(items.len(), 1);
    assert!(items[0].is_missing(), "dead remote surfaced as a placeholder");
    // The placeholder is backed by the soft-error machinery, not silence:
    // the failed read was counted soft and recovery was attempted (and
    // failed — no target can reach the bucket).
    let soft: u64 = c.targets.iter().map(|t| t.metrics.soft_errors.get()).sum();
    assert!(soft > 0, "tolerated failure counted as a soft error");
    let failures: u64 = c.targets.iter().map(|t| t.metrics.recovery_failures.get()).sum();
    assert!(failures > 0, "recovery against a dead remote fails, and is counted");
    let hard: u64 = c.targets.iter().map(|t| t.metrics.hard_failures.get()).sum();
    assert_eq!(hard, 0, "continue-on-error aborted nothing");

    // Non-coer mode must surface a *typed* I/O failure to the client — a
    // truncated stream decoded as ClientError::Io/Tar(Io) — never a
    // placeholder item pretending the object is merely missing.
    let strict = BatchRequest::new(vec![BatchEntry::obj("rb", "gone")]);
    match client.get_batch_collect(&strict) {
        Err(getbatch::client::sdk::ClientError::Tar(getbatch::tar::TarError::Io(_)))
        | Err(getbatch::client::sdk::ClientError::Io(_)) => {}
        other => panic!("expected a typed Io error, got {other:?}"),
    }
    let hard: u64 = c.targets.iter().map(|t| t.metrics.hard_failures.get()).sum();
    assert!(hard > 0, "non-coer abort counted as a hard failure");
}

#[test]
fn gfn_recovers_remote_bucket_entry_from_local_replica() {
    // Bucket `rb` is remote-routed only on the entry's HRW owner; every
    // other target keeps a local replica. Kill the storage cluster: the
    // owner's reads fail (connection refused → Io surfaced as a soft
    // error), and GFN must still complete the batch from a neighbor's
    // local copy.
    let storage = fixtures::cluster(1);
    let data = payload(40 << 10, 77);
    storage.put_direct("rb", "precious", &data).unwrap();

    let c = getbatch::Cluster::start(ClusterConfig {
        targets: 3,
        http_workers: 4,
        getbatch: GetBatchConfig {
            sender_wait: Duration::from_millis(2000),
            gfn_attempts: 3,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let owner = placement::owner(&c.smap, "rb/precious");
    c.route_remote_bucket_on(owner, "rb", &[&storage.proxy_addr()], false);
    for (i, t) in c.targets.iter().enumerate() {
        if i != owner {
            t.store.local().put("rb", "precious", &data).unwrap();
        }
    }
    drop(storage); // storage node down

    let client = Client::new(&c.proxy_addr());
    let items = client
        .get_batch_collect(&BatchRequest::new(vec![BatchEntry::obj("rb", "precious")]))
        .unwrap();
    assert_eq!(items.len(), 1);
    assert_eq!(items[0].data().unwrap(), &data[..], "recovered byte-identically");
    let attempts: u64 = c.targets.iter().map(|t| t.metrics.recovery_attempts.get()).sum();
    assert!(attempts > 0, "recovery path exercised");
}

#[test]
fn config_driven_bucket_routing() {
    // Buckets declared in GetBatchConfig get their stacks installed at
    // boot: a local+cached bucket serves through the node cache.
    let c = getbatch::Cluster::start(ClusterConfig {
        targets: 2,
        http_workers: 4,
        getbatch: GetBatchConfig {
            cache_bytes: 1 << 20,
            buckets: vec![getbatch::config::BucketSpec {
                name: "hot".into(),
                backend: "local".into(),
                remote_addrs: Vec::new(),
                cache: true,
            }],
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let data = payload(64 << 10, 5);
    c.put_direct("hot", "o", &data).unwrap();
    let client = Client::new(&c.proxy_addr());
    let req = BatchRequest::new(vec![BatchEntry::obj("hot", "o")]);
    let items = client.get_batch_collect(&req).unwrap();
    assert_eq!(items[0].data().unwrap(), &data[..]);
    let _ = client.get_batch_collect(&req).unwrap();
    let hits: u64 = c.targets.iter().map(|t| t.metrics.cache_hits.get()).sum();
    assert!(hits > 0, "cached local bucket served hits");
    let misses: u64 = c.targets.iter().map(|t| t.metrics.cache_misses.get()).sum();
    assert!(misses > 0, "first read was a cold miss");
}

#[test]
fn misconfigured_bucket_spec_refuses_to_boot() {
    for (backend, addrs) in [("remote", vec![]), ("s3", vec!["10.0.0.1:80".to_string()])] {
        let bad = ClusterConfig {
            targets: 1,
            getbatch: GetBatchConfig {
                buckets: vec![getbatch::config::BucketSpec {
                    name: "hot".into(),
                    backend: backend.into(),
                    remote_addrs: addrs.clone(),
                    cache: false,
                }],
                ..Default::default()
            },
            ..Default::default()
        };
        assert!(
            getbatch::Cluster::start(bad).is_err(),
            "spec backend={backend} addrs={addrs:?} must refuse to boot"
        );
    }
}

#[test]
fn remote_bucket_roundtrips_through_router_put() {
    // Writing through a remote-routed bucket lands the object (and its CRC
    // sidecar) on the storage cluster.
    let storage = fixtures::cluster(1);
    let c = serving_cluster(&storage.proxy_addr(), false);
    let data = payload(10 << 10, 9);
    c.targets[0].store.put("rb", "written", &data).unwrap();
    assert_eq!(
        storage.targets[0].store.local().get("rb", "written").unwrap(),
        data,
        "write-through to storage"
    );
    // Readable from every serving target through the remote tier.
    for t in &c.targets {
        assert_eq!(t.store.get("rb", "written").unwrap(), data);
    }
}
