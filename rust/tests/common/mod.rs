//! Shared support for the integration suites: cluster spin-up, seeded
//! payloads/tempdirs, metric scraping, and the bounded retry-once guard
//! for timing-sensitive comparative assertions.
//!
//! Compiled into each `[[test]]` target via `mod common;` — not every
//! suite uses every helper, hence the file-wide `dead_code` allow.
#![allow(dead_code)]

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use getbatch::cluster::node::TargetNode;
use getbatch::config::{ClusterConfig, GetBatchConfig};
use getbatch::util::rng::Rng;
use getbatch::Cluster;

/// Seeded random payload: same (n, seed) ⇒ same bytes, in every suite.
pub fn payload(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let mut buf = vec![0u8; n];
    rng.fill_bytes(&mut buf);
    buf
}

/// Start a cluster with the given shape and GetBatch knobs (everything
/// else defaulted) — the spin-up line every suite used to hand-roll.
pub fn start_cluster(targets: usize, http_workers: usize, gb: GetBatchConfig) -> Cluster {
    Cluster::start(ClusterConfig {
        targets,
        http_workers,
        getbatch: gb,
        ..Default::default()
    })
    .unwrap()
}

/// Serving cluster fronting bucket `rb` from `storage_addr` through each
/// target's chunk cache — the standard tiered-test topology.
pub fn serving_rb(storage_addr: &str, targets: usize, gb: GetBatchConfig) -> Cluster {
    let c = start_cluster(targets, 4, gb);
    c.route_remote_bucket("rb", &[storage_addr], true);
    c
}

/// Sum a per-target counter across the cluster (metric scraping).
pub fn sum(c: &Cluster, f: impl Fn(&TargetNode) -> u64) -> u64 {
    c.targets.iter().map(f).sum()
}

static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Fresh process-unique temp directory for store-backed tests; caller (or
/// the OS) cleans up.
pub fn seeded_tempdir(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!(
        "gb-{tag}-{}-{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

/// Bounded retry-once guard for timing-sensitive *comparative* assertions
/// (P99 ON beats OFF, wall-time ON beats OFF): genuinely broken behavior
/// fails twice in a row, a single CI scheduling hiccup does not. The
/// repro seed is printed on every failure path so a flake can be replayed.
pub fn retry_once<T>(
    label: &str,
    repro_seed: u64,
    mut attempt: impl FnMut() -> Result<T, String>,
) -> T {
    match attempt() {
        Ok(v) => v,
        Err(first) => {
            eprintln!(
                "{label}: first attempt failed ({first}); retrying once \
                 (repro seed {repro_seed})"
            );
            match attempt() {
                Ok(v) => v,
                Err(second) => {
                    panic!("{label}: failed twice — {second} (repro seed {repro_seed})")
                }
            }
        }
    }
}
