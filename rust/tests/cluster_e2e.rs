//! End-to-end cluster tests: the full GetBatch execution flow over real
//! localhost TCP, covering ordering at scale, execution options, metrics,
//! colocation, multi-proxy routing and concurrent batches.

use std::collections::HashSet;
use std::sync::Arc;

use getbatch::batch::request::{BatchEntry, BatchRequest};
use getbatch::client::loader::{AccessMode, DataLoader};
use getbatch::client::sdk::Client;
use getbatch::cluster::node::Cluster;
use getbatch::config::{ClusterConfig, GetBatchConfig};
use getbatch::metrics::GetBatchMetrics;
use getbatch::testutil::fixtures;
use getbatch::util::threadpool::scoped_map;

#[test]
fn large_batch_strict_ordering_across_nodes() {
    let c = fixtures::cluster(4);
    let names = fixtures::stage_objects(&c, "b", 300, 2048, 1);
    let client = Client::new(&c.proxy_addr());
    // request in a scrambled order; response must match it exactly
    let mut order: Vec<usize> = (0..300).collect();
    let mut rng = getbatch::util::rng::Rng::new(9);
    rng.shuffle(&mut order);
    let entries: Vec<BatchEntry> =
        order.iter().map(|&i| BatchEntry::obj("b", &names[i])).collect();
    let items = client.get_batch_collect(&BatchRequest::new(entries)).unwrap();
    assert_eq!(items.len(), 300);
    for (k, &i) in order.iter().enumerate() {
        assert_eq!(items[k].name(), names[i], "position {k}");
    }
}

#[test]
fn duplicate_entries_allowed_and_ordered() {
    let c = fixtures::cluster(2);
    fixtures::stage_objects(&c, "b", 4, 256, 2);
    let client = Client::new(&c.proxy_addr());
    let entries = vec![
        BatchEntry::obj("b", "obj-000001"),
        BatchEntry::obj("b", "obj-000001"),
        BatchEntry::obj("b", "obj-000003"),
        BatchEntry::obj("b", "obj-000001"),
    ];
    let items = client.get_batch_collect(&BatchRequest::new(entries)).unwrap();
    assert_eq!(items.len(), 4);
    assert_eq!(items[0].data(), items[1].data());
    assert_eq!(items[0].data(), items[3].data());
}

#[test]
fn buffered_vs_streaming_same_bytes() {
    let c = fixtures::cluster(3);
    let names = fixtures::stage_objects(&c, "b", 40, 1500, 3);
    let client = Client::new(&c.proxy_addr());
    let entries: Vec<BatchEntry> = names.iter().map(|n| BatchEntry::obj("b", n)).collect();
    let strm = client
        .get_batch_collect(&BatchRequest::new(entries.clone()).streaming(true))
        .unwrap();
    let buf = client
        .get_batch_collect(&BatchRequest::new(entries).streaming(false))
        .unwrap();
    assert_eq!(strm, buf);
}

#[test]
fn mixed_objects_and_shard_members_one_request() {
    let c = fixtures::cluster(3);
    fixtures::stage_objects(&c, "plain", 5, 700, 4);
    let manifest = fixtures::stage_shards(&c, "audio", 3, 8, 1024.0, 5);
    let client = Client::new(&c.proxy_addr());
    let sref = &manifest.samples[7];
    let entries = vec![
        BatchEntry::obj("plain", "obj-000002"),
        sref.to_entry(),
        BatchEntry::obj("plain", "obj-000004"),
    ];
    let items = client.get_batch_collect(&BatchRequest::new(entries)).unwrap();
    assert_eq!(items.len(), 3);
    assert_eq!(items[1].data().unwrap().len() as u64, sref.size);
}

#[test]
fn colocation_hint_reduces_cross_node_traffic() {
    let c = fixtures::cluster(4);
    // one shard = one owner: perfectly colocatable workload
    let manifest = fixtures::stage_shards(&c, "audio", 1, 64, 2048.0, 6);
    let client = Client::new(&c.proxy_addr());
    let entries: Vec<BatchEntry> =
        manifest.samples.iter().take(32).map(|s| s.to_entry()).collect();

    let run = |coloc: bool| -> f64 {
        let before: f64 = c
            .targets
            .iter()
            .map(|t| t.metrics.sender_entries.get() as f64)
            .sum();
        for _ in 0..4 {
            client
                .get_batch_collect(
                    &BatchRequest::new(entries.clone()).colocation(coloc),
                )
                .unwrap();
        }
        let after: f64 = c
            .targets
            .iter()
            .map(|t| t.metrics.sender_entries.get() as f64)
            .sum();
        after - before
    };
    let without = run(false);
    let with = run(true);
    // with colocation the DT owns the shard: zero sender entries cross nodes
    assert_eq!(with, 0.0, "colocated batches need no P2P sender traffic");
    assert!(without > 0.0 || with == 0.0);
}

#[test]
fn streaming_batch_larger_than_dt_memory_budget_is_correct_and_bounded() {
    // The §2.3.1 streaming claim made falsifiable: total payload (3 MiB)
    // exceeds the DT's enforced memory budget (256 KiB) many times over.
    // The batch must still assemble byte-identically in strict order, and
    // no target's resident bytes may ever exceed the budget.
    let gb = GetBatchConfig {
        chunk_bytes: 64 << 10,
        dt_buffer_bytes: 256 << 10,
        ..Default::default()
    };
    let c = fixtures::cluster_cfg(3, gb);
    let mut rng = getbatch::util::rng::Rng::new(0xB16);
    let mut want = Vec::new();
    for i in 0..6 {
        let mut data = vec![0u8; 512 << 10];
        rng.fill_bytes(&mut data);
        c.put_direct("b", &format!("big-{i}"), &data).unwrap();
        want.push(data);
    }

    let client = Client::new(&c.proxy_addr());
    let entries: Vec<BatchEntry> =
        (0..6).map(|i| BatchEntry::obj("b", &format!("big-{i}"))).collect();
    let items = client
        .get_batch_collect(&BatchRequest::new(entries).streaming(true))
        .unwrap();

    assert_eq!(items.len(), 6);
    for (i, item) in items.iter().enumerate() {
        assert_eq!(item.name(), format!("big-{i}"), "strict order at position {i}");
        assert_eq!(item.data().unwrap(), &want[i][..], "entry {i} byte-identical");
    }
    for t in &c.targets {
        assert!(
            t.budget.peak() <= t.budget.budget(),
            "target {}: peak resident {} exceeded budget {}",
            t.info.id,
            t.budget.peak(),
            t.budget.budget()
        );
        assert_eq!(t.budget.overruns(), 0, "target {}: forced admissions", t.info.id);
    }
    // The budget actually bit on the DT (3 MiB streamed through 256 KiB).
    let peak_max = c.targets.iter().map(|t| t.budget.peak()).max().unwrap();
    assert!(peak_max > 0, "some DT buffered bytes");
}

#[test]
fn admission_control_rejects_with_429_under_memory_pressure() {
    let cfg = ClusterConfig {
        targets: 1,
        getbatch: GetBatchConfig { mem_critical_bytes: 1, ..Default::default() },
        ..Default::default()
    };
    let c = Cluster::start(cfg).unwrap();
    // Preload the gauge: the admission check reads dt_buffered_bytes.
    c.targets[0].metrics.dt_buffered_bytes.set(10);
    fixtures::stage_objects(&c, "b", 2, 128, 7);
    let client = Client::new(&c.proxy_addr());
    let err = client
        .get_batch_collect(&BatchRequest::new(vec![BatchEntry::obj("b", "obj-000000")]))
        .unwrap_err();
    match err {
        getbatch::client::sdk::ClientError::Status { status, .. } => assert_eq!(status, 429),
        other => panic!("expected 429, got {other:?}"),
    }
    // The rejection carries a Retry-After derived from the budget's
    // patience window, and the proxy propagates it to the client untouched.
    let http = getbatch::proto::http::HttpClient::new(true);
    let req = BatchRequest::new(vec![BatchEntry::obj("b", "obj-000000")]);
    let resp = http
        .request("GET", &c.proxy_addr(), getbatch::proto::wire::paths::BATCH, &req.to_body())
        .unwrap();
    assert_eq!(resp.status, 429);
    let ra: u64 = resp
        .header("retry-after")
        .expect("429 carries retry-after")
        .trim()
        .parse()
        .expect("integral seconds");
    let want = c.cfg.getbatch.budget_patience.as_secs().max(1);
    assert_eq!(ra, want, "back-off advertises the budget patience window");
    let _ = resp.into_bytes();
}

#[test]
fn metrics_expose_rxwait_and_composition() {
    let c = fixtures::cluster(3);
    let manifest = fixtures::stage_shards(&c, "audio", 2, 10, 1024.0, 8);
    fixtures::stage_objects(&c, "b", 10, 512, 9);
    let client = Client::new(&c.proxy_addr());
    let mut entries: Vec<BatchEntry> =
        manifest.samples.iter().take(8).map(|s| s.to_entry()).collect();
    entries.push(BatchEntry::obj("b", "obj-000001"));
    client.get_batch_collect(&BatchRequest::new(entries)).unwrap();

    let mut members = 0.0;
    let mut objs = 0.0;
    let mut work = 0.0;
    for t in &c.targets {
        let text = client.metrics(&t.info.http_addr).unwrap();
        let m = GetBatchMetrics::parse(&text);
        members += m["ais_getbatch_members_extracted_total"];
        objs += m["ais_getbatch_objects_delivered_total"];
        work += m["ais_getbatch_work_items_total"];
    }
    assert_eq!(members, 8.0);
    assert_eq!(objs, 1.0);
    assert_eq!(work, 9.0);
}

#[test]
fn concurrent_batches_from_many_clients() {
    let c = Arc::new(fixtures::cluster(3));
    let names = fixtures::stage_objects(&c, "b", 64, 1024, 10);
    let proxy = c.proxy_addr();
    let results = scoped_map(&(0..12u64).collect::<Vec<_>>(), 12, |_, &i| {
        let client = Client::new(&proxy);
        let mut rng = getbatch::util::rng::Rng::new(i + 100);
        let entries: Vec<BatchEntry> = (0..24)
            .map(|_| BatchEntry::obj("b", &names[rng.usize_below(64)]))
            .collect();
        let want: Vec<String> = entries.iter().map(|e| e.output_name()).collect();
        let items = client.get_batch_collect(&BatchRequest::new(entries)).unwrap();
        (want, items.iter().map(|it| it.name().to_string()).collect::<Vec<_>>())
    });
    for (want, got) in results {
        assert_eq!(want, got);
    }
    // DT load spread across targets (mixed roles, §2.3.1)
    let dts: HashSet<usize> = c
        .targets
        .iter()
        .enumerate()
        .filter(|(_, t)| t.metrics.dt_requests.get() > 0)
        .map(|(i, _)| i)
        .collect();
    assert!(dts.len() >= 2, "DT role should rotate across nodes: {dts:?}");
}

#[test]
fn multi_proxy_cluster_routes_from_any_gateway() {
    let c = Cluster::start(ClusterConfig { targets: 2, proxies: 3, ..Default::default() }).unwrap();
    fixtures::stage_objects(&c, "b", 8, 256, 11);
    for p in &c.proxies {
        let client = Client::new(&p.info.http_addr);
        let items = client
            .get_batch_collect(&BatchRequest::new(vec![
                BatchEntry::obj("b", "obj-000000"),
                BatchEntry::obj("b", "obj-000007"),
            ]))
            .unwrap();
        assert_eq!(items.len(), 2, "via proxy {}", p.info.id);
    }
}

#[test]
fn training_loaders_converge_on_same_data() {
    // All three access modes must deliver identical sample *sets* given the
    // same manifest (sampling differs, content fidelity must not).
    let c = fixtures::cluster(3);
    let manifest = fixtures::stage_shards(&c, "audio", 4, 8, 512.0, 12);
    let by_name: std::collections::HashMap<String, u64> =
        manifest.samples.iter().map(|s| (s.name.clone(), s.size)).collect();
    for mode in [AccessMode::Sequential, AccessMode::RandomGet, AccessMode::GetBatch] {
        let mut dl =
            DataLoader::new(Client::new(&c.proxy_addr()), manifest.clone(), mode, 8, 13);
        let (samples, _) = dl.next_batch().unwrap();
        for s in &samples {
            let want = by_name[s.name.trim_start_matches(|c: char| c != 'u')];
            assert_eq!(s.data.len() as u64, want, "{mode:?} sample {}", s.name);
        }
    }
}

#[test]
fn sender_residency_bounded_by_chunk_for_multi_mib_entry() {
    // ISSUE 2 acceptance: a multi-MiB entry pushed through a small
    // chunk_bytes must never materialize more than ~2x chunk on the sender
    // side — streaming reads (EntryReader) made observable through the
    // sender_peak_buffer high-water mark.
    let chunk = 64 << 10;
    let gb = GetBatchConfig { chunk_bytes: chunk, dt_buffer_bytes: 512 << 10, ..Default::default() };
    let c = fixtures::cluster_cfg(3, gb);
    let mut rng = getbatch::util::rng::Rng::new(0x5EED);
    let mut big = vec![0u8; 3 << 20]; // 3 MiB
    rng.fill_bytes(&mut big);
    c.put_direct("b", "huge", &big).unwrap();
    // Pin the DT away from the huge object's owner: two colocation anchors
    // owned by a *different* target make that target the colocated DT, so
    // the huge entry deterministically crosses the P2P sender path.
    let huge_owner = getbatch::cluster::placement::owner(&c.smap, "b/huge");
    let anchor = (huge_owner + 1) % c.targets.len();
    let mut pads = Vec::new();
    let mut i = 0;
    while pads.len() < 2 {
        let name = format!("pad-{i}");
        if getbatch::cluster::placement::owner(&c.smap, &format!("b/{name}")) == anchor {
            c.put_direct("b", &name, b"pad").unwrap();
            pads.push(name);
        }
        i += 1;
    }

    let client = Client::new(&c.proxy_addr());
    let mut entries = vec![BatchEntry::obj("b", "huge")];
    entries.extend(pads.iter().map(|p| BatchEntry::obj("b", p)));
    let items =
        client.get_batch_collect(&BatchRequest::new(entries).colocation(true)).unwrap();
    assert_eq!(items.len(), 3);
    assert_eq!(items[0].data().unwrap(), &big[..], "3 MiB entry byte-identical");

    let peak = c.targets[huge_owner].metrics.sender_peak_buffer.get();
    assert!(peak > 0, "the huge object's owner recorded its peak sender buffer");
    assert!(
        peak <= 2 * chunk as i64,
        "sender-side allocation {peak} exceeded 2x chunk_bytes ({chunk})"
    );
    assert!(
        c.targets[huge_owner].metrics.sender_chunks.get() >= 40,
        "3 MiB entry crossed the wire in many chunk frames"
    );
}

#[test]
fn target_object_endpoint_serves_http_ranges() {
    // HTTP Range roundtrip against a live target: whole-object GET still
    // works (now chunked-streamed), ranged GETs return 206 slices with the
    // total advertised in content-range, and past-EOF starts yield 416.
    let c = fixtures::cluster(2);
    let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
    c.put_direct("b", "obj", &data).unwrap();
    let owner = getbatch::cluster::placement::owner(&c.smap, "b/obj");
    let addr = c.target_addr(owner);
    let http = getbatch::proto::http::HttpClient::new(true);
    let pq = "/v1/objects/b/obj?local=true";

    let whole = http.get(&addr, pq).unwrap();
    assert_eq!(whole.status, 200);
    assert_eq!(whole.into_bytes().unwrap(), data);

    // rebuild via ranged chunks
    let mut rebuilt = Vec::new();
    let mut off = 0u64;
    loop {
        let resp = http.get_range(&addr, pq, off, 16 << 10).unwrap();
        assert_eq!(resp.status, 206);
        let total = getbatch::proto::http::content_range_total(
            resp.header("content-range").unwrap(),
        )
        .unwrap();
        assert_eq!(total, data.len() as u64);
        let bytes = resp.into_bytes().unwrap();
        off += bytes.len() as u64;
        rebuilt.extend_from_slice(&bytes);
        if off >= total {
            break;
        }
    }
    assert_eq!(rebuilt, data);

    let past = http.get_range(&addr, pq, 10_000_000, 1024).unwrap();
    assert_eq!(past.status, 416);

    // shard members are ranged too (range applies within the member span)
    let entries = vec![
        getbatch::tar::Entry { name: "m0".into(), data: vec![7u8; 5000] },
        getbatch::tar::Entry { name: "m1".into(), data: (0..200u8).cycle().take(9000).collect() },
    ];
    c.put_direct("b", "s.tar", &getbatch::tar::write_archive(&entries).unwrap()).unwrap();
    let owner = getbatch::cluster::placement::owner(&c.smap, "b/s.tar");
    let addr = c.target_addr(owner);
    let resp = http
        .get_range(&addr, "/v1/objects/b/s.tar?local=true&archpath=m1", 4000, 2000)
        .unwrap();
    assert_eq!(resp.status, 206);
    let total =
        getbatch::proto::http::content_range_total(resp.header("content-range").unwrap()).unwrap();
    assert_eq!(total, 9000, "member length, not shard length");
    let bytes = resp.into_bytes().unwrap();
    assert_eq!(bytes, &entries[1].data[4000..6000], "member slice");
}
