//! The scale gate: time-virtualized populations driven through the *real*
//! admission / order-buffer / cache code by `sim::scale`. In release mode
//! (CI's `sim_scale` job) the storm scenario registers ≥ 1,000,000 clients
//! in under 60 s of wall clock; debug builds default to a 50,000-client
//! smoke of the same paths so plain `cargo test` stays fast.
//!
//! Knobs:
//! - `GETBATCH_SIM_SEED`    — workload seed (CI pins two; failures print it)
//! - `GETBATCH_SIM_CLIENTS` — population override for either build profile
//!
//! Every scenario asserts the four harness invariants from the report —
//! peak resident ≤ `dt_buffer_bytes`, cache occupancy ≤ `cache_bytes`,
//! zero patience-valve overruns, bounded admission wait — and the storm
//! scenario additionally proves determinism: two same-seed runs produce
//! byte-identical reports (trace hash included).

use std::time::Instant;

use getbatch::sim::scale::{run_scale, ScaleConfig, ScaleReport};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn seed() -> u64 {
    env_u64("GETBATCH_SIM_SEED", 0x5CA1E)
}

/// Full-scale population in release, a smoke-scale one in debug: the event
/// loop is an order of magnitude slower without optimizations, and the
/// million-client bar is the release job's to hold.
fn population() -> u64 {
    let default = if cfg!(debug_assertions) { 50_000 } else { 1_000_000 };
    env_u64("GETBATCH_SIM_CLIENTS", default)
}

fn assert_invariants(tag: &str, seed: u64, cfg: &ScaleConfig, r: &ScaleReport) {
    assert_eq!(
        r.completed, r.clients,
        "{tag}: every client must complete (seed {seed})"
    );
    assert!(
        r.peak_resident <= r.dt_buffer_bytes,
        "{tag}: peak resident {} exceeded dt_buffer_bytes {} (seed {seed})",
        r.peak_resident,
        r.dt_buffer_bytes
    );
    assert!(
        r.cache_peak <= r.cache_bytes,
        "{tag}: cache occupancy {} exceeded cache_bytes {} (seed {seed})",
        r.cache_peak,
        r.cache_bytes
    );
    assert_eq!(
        r.overruns, 0,
        "{tag}: backpressured deliveries must never trip the patience valve (seed {seed})"
    );
    assert!(
        r.max_admission_wait_ns <= cfg.starvation_bound_ns,
        "{tag}: a registration waited {} ns, past the {} ns fairness bound (seed {seed})",
        r.max_admission_wait_ns,
        cfg.starvation_bound_ns
    );
}

/// The headline gate: a uniform small-object storm at the full population,
/// run twice with the same seed. Invariants hold on both runs, the two
/// reports are identical down to the trace hash, and (release only) each
/// run fits the 60 s wall budget.
#[test]
fn storm_at_full_population_is_bounded_deterministic_and_fast() {
    let (seed, clients) = (seed(), population());
    let cfg = ScaleConfig::storm(clients, seed);

    let t0 = Instant::now();
    let first = run_scale(&cfg);
    let first_wall = t0.elapsed();
    let t1 = Instant::now();
    let second = run_scale(&cfg);
    let second_wall = t1.elapsed();

    println!(
        "storm: {} clients, {} events, virtual {:.3} s, wall {:.1?} + {:.1?}, \
         peak {}/{} B, cache {}/{} B, rejected {}, backpressured {}, \
         trace {:#018x} (seed {seed})",
        first.clients,
        first.events,
        first.virtual_ns as f64 / 1e9,
        first_wall,
        second_wall,
        first.peak_resident,
        first.dt_buffer_bytes,
        first.cache_peak,
        first.cache_bytes,
        first.rejected,
        first.backpressured,
        first.trace_hash,
    );

    assert_invariants("storm", seed, &cfg, &first);
    assert_eq!(
        first, second,
        "same seed must reproduce the identical report, trace hash included (seed {seed})"
    );

    // The wall budget is a release-profile promise; debug runs the same
    // paths at smoke scale without timing them.
    #[cfg(not(debug_assertions))]
    for (tag, wall) in [("first", first_wall), ("second", second_wall)] {
        assert!(
            wall.as_secs() < 60,
            "storm {tag} run took {wall:?}, past the 60 s wall budget \
             ({clients} clients, seed {seed})"
        );
    }
}

/// Zipf hot-shard mix at a quarter of the population: the cache carries the
/// load (hits strictly outnumber misses) and every invariant still holds.
#[test]
fn zipf_hot_shards_hold_invariants_and_concentrate_hits() {
    let (seed, clients) = (seed(), population() / 4);
    let cfg = ScaleConfig::zipf(clients.max(1), seed);
    let r = run_scale(&cfg);
    assert_invariants("zipf", seed, &cfg, &r);
    assert!(
        r.cache_hits > r.cache_misses,
        "zipf head must be cache-resident: {} hits vs {} misses (seed {seed})",
        r.cache_hits,
        r.cache_misses
    );
}

/// EpochPlan replay at a quarter of the population: the training-fleet
/// access pattern (PR 8 shuffles) through the same real components.
#[test]
fn epoch_replay_holds_invariants_at_scale() {
    let (seed, clients) = (seed(), population() / 4);
    let cfg = ScaleConfig::epoch_replay(clients.max(1), seed);
    let r = run_scale(&cfg);
    assert_invariants("epoch_replay", seed, &cfg, &r);
    assert!(r.cache_hits + r.cache_misses > 0, "replay exercised the cache (seed {seed})");
}

/// The trace hash is a real fingerprint: a different seed produces a
/// different trace (at smoke scale — this is a property of the hash, not
/// of the population).
#[test]
fn different_seeds_produce_different_traces() {
    let seed = seed();
    let a = run_scale(&ScaleConfig::storm(10_000, seed));
    let b = run_scale(&ScaleConfig::storm(10_000, seed ^ 0xDEAD_BEEF));
    assert_ne!(
        a.trace_hash, b.trace_hash,
        "distinct seeds must not collide on the trace hash (seed {seed})"
    );
}
