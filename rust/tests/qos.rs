//! Multi-tenant QoS gate: fair-share admission isolates a well-behaved
//! tenant from a misbehaving one (tentpole), load shedding drops the
//! lowest priority class first with class-scaled `Retry-After` hints, and
//! tenant identity flows end-to-end into per-tenant metrics.
//!
//! Repro knob: `GETBATCH_QOS_SEED` pins the payload seed (printed on every
//! timing-assertion failure so a flake can be replayed).

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use common::{payload, retry_once, start_cluster, sum};
use getbatch::proto::http::HttpClient;
use getbatch::proto::wire::{self, paths, DtRegister};
use getbatch::{BatchEntry, BatchRequest, Client, GetBatchConfig};

fn qos_seed() -> u64 {
    std::env::var("GETBATCH_QOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x9057)
}

/// Register a batch directly at a target's DT endpoint with an explicit
/// tenant/priority, bypassing the proxy. The single entry names an absent
/// object so the DT-local resolver fails the slot without reserving budget
/// bytes — the registration pins only the tenant's *activity* (its ledger
/// handle), never memory, and `num_senders = 1` keeps it parked in the
/// registry (no sender ever arrives) until the abandon reaper collects it.
fn register_raw(
    http: &HttpClient,
    addr: &str,
    req_id: u64,
    tenant: &str,
    priority: &str,
) -> (u16, Option<String>) {
    let raw = String::from_utf8(
        BatchRequest::new(vec![BatchEntry::obj("qos", "absent-object")]).to_body(),
    )
    .unwrap();
    let body = DtRegister::body_with_raw_qos(req_id, 1, tenant, priority, &raw);
    let resp = http.request("POST", addr, paths::DT_REGISTER, &body).unwrap();
    let status = resp.status;
    let retry_after = resp.header("retry-after").map(|s| s.to_string());
    let _ = resp.into_bytes();
    (status, retry_after)
}

/// Tentpole: a tenant that registers a batch several times the node's
/// entire DT buffer and then never drains its stream must not starve a
/// well-behaved tenant. With the fair-share ledger the hog is capped at
/// its share of the budget cap, so the steady tenant's rounds run at its
/// solo pace (within 10%) and the budget's patience valve (forced
/// overrun admissions) never fires. Without the ledger the hog pins the
/// whole cap and every steady producer blocks for the full patience
/// window.
#[test]
fn hog_tenant_cannot_starve_steady_tenant() {
    let seed = qos_seed();
    retry_once("two-tenant fairness", seed, || {
        let gb = GetBatchConfig {
            dt_buffer_bytes: 1 << 20,
            chunk_bytes: 32 << 10,
            // Shedding out of the picture: this test isolates fair shares.
            mem_critical_bytes: 64 << 20,
            budget_patience: Duration::from_secs(5),
            ..Default::default()
        };
        let c = start_cluster(1, 4, gb);
        let t = &c.targets[0];

        let steady =
            Client::new(&c.proxy_addr()).with_tenant("steady").with_priority("interactive");
        let hog = Client::new(&c.proxy_addr()).with_tenant("hog").with_priority("bulk");
        for i in 0..12u64 {
            steady.put("b", &format!("s{i}"), &payload(32 << 10, seed ^ i)).unwrap();
        }
        for i in 0..40u64 {
            hog.put("b", &format!("h{i}"), &payload(256 << 10, seed ^ (0x100 + i))).unwrap();
        }

        // Keepalive: park one zero-byte steady registration so the steady
        // tenant stays *active* across the gaps between measured rounds.
        // Shares are divided among active tenants only — without this, the
        // hog becomes sole-active in each inter-round gap, borrows the
        // whole cap (by design: idle shares are borrowable), and the
        // already-resident bytes can't be clawed back when steady returns.
        let http = HttpClient::new(true);
        let (status, _) =
            register_raw(&http, &t.info.http_addr, 0x5EED_0001, "steady", "interactive");
        assert_eq!(status, 200, "keepalive registration refused");

        // Slow, deterministic reads: wall time measures data-path
        // throughput, not request-dispatch noise.
        t.store.local().set_latency(Duration::from_millis(2), 1.0);

        let steady_req = BatchRequest::new(
            (0..12).map(|i| BatchEntry::obj("b", &format!("s{i}"))).collect(),
        );
        let rounds = 8;
        let run = |label: &str| -> Result<Duration, String> {
            let t0 = Instant::now();
            for r in 0..rounds {
                let items = steady
                    .get_batch_collect(&steady_req)
                    .map_err(|e| format!("{label} round {r}: {e}"))?;
                if items.len() != 12 {
                    return Err(format!("{label} round {r}: short batch ({})", items.len()));
                }
            }
            Ok(t0.elapsed())
        };

        let solo = run("solo")?;

        // Contended phase: the hog registers a 10 MiB batch (10× the node
        // budget) and sits on the stream without reading a byte, so its
        // resident bytes pin at whatever admission grants for the whole
        // phase. Dropping the reader at the end aborts the stream.
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let hog2 = hog.clone();
        let hog_thread = thread::spawn(move || {
            let req = BatchRequest::new(
                (0..40).map(|i| BatchEntry::obj("b", &format!("h{i}"))).collect(),
            );
            let reader = hog2.get_batch(&req);
            while !stop2.load(Ordering::Relaxed) {
                thread::sleep(Duration::from_millis(5));
            }
            drop(reader);
        });
        // Let the hog wedge in and fill to its cap before measuring.
        thread::sleep(Duration::from_millis(150));

        let contended = run("contended");
        stop.store(true, Ordering::Relaxed);
        hog_thread.join().unwrap();
        let contended = contended?;

        let overruns = sum(&c, |t| t.metrics.budget_overruns.get());
        if overruns != 0 {
            return Err(format!("budget patience valve fired {overruns}× under a hog"));
        }
        // Within 10% of the solo baseline (+ a small absolute grace for
        // scheduler jitter on a ~250 ms measurement).
        let limit = solo.mul_f64(1.10) + Duration::from_millis(30);
        if contended > limit {
            return Err(format!(
                "steady tenant degraded: solo {solo:?}, contended {contended:?} (limit {limit:?})"
            ));
        }
        Ok(())
    });
}

/// Load shedding is lowest-class-first: as buffered bytes climb toward
/// `mem_critical_bytes`, bulk is rejected at 1/2 of critical, batch at
/// 3/4, interactive only at the full threshold — and each 429 carries a
/// `Retry-After` scaled by the class backoff factor (patience 2 s ⇒
/// interactive "2", batch "4", bulk "8"), so recovered headroom is
/// retried into by interactive work first.
#[test]
fn shedding_drops_lowest_class_first_with_scaled_backoff() {
    let gb = GetBatchConfig {
        dt_buffer_bytes: 4 << 20,
        chunk_bytes: 64 << 10,
        mem_critical_bytes: 1 << 20,
        budget_patience: Duration::from_secs(2),
        ..Default::default()
    };
    let c = start_cluster(1, 4, gb);
    let t = &c.targets[0];
    let http = HttpClient::new(true);
    let mut next_id = 0xbee0_u64;
    let mut register = |class: &str| {
        next_id += 1;
        register_raw(&http, &t.info.http_addr, next_id, "shed-test", class)
    };

    // 600 KiB buffered: past bulk's half-critical threshold only.
    t.metrics.dt_buffered_bytes.set(600 << 10);
    assert_eq!(register("bulk"), (429, Some("8".into())), "bulk sheds first, longest backoff");
    assert_eq!(register("batch").0, 200, "batch still admits at 600 KiB");
    assert_eq!(register("interactive").0, 200);

    // 800 KiB: past batch's three-quarter threshold.
    t.metrics.dt_buffered_bytes.set(800 << 10);
    assert_eq!(register("batch"), (429, Some("4".into())), "batch sheds next");
    assert_eq!(register("interactive").0, 200, "interactive admits until critical");

    // At critical: everyone sheds, interactive with the shortest hint.
    t.metrics.dt_buffered_bytes.set(1 << 20);
    assert_eq!(register("interactive"), (429, Some("2".into())));

    // An unknown class label falls back to the configured default
    // ("batch"), which is shed at this level too.
    assert_eq!(register("turbo").0, 429);

    let rejects = sum(&c, |t| t.metrics.admission_rejects.get());
    assert_eq!(rejects, 4, "one admission reject per 429");
}

/// Tenant identity flows from the client SDK through the proxy's register
/// body into the DT's per-tenant metrics; legacy clients (no QoS headers)
/// are accounted under the default tenant.
#[test]
fn tenant_identity_lands_in_per_tenant_metrics() {
    let c = start_cluster(1, 4, GetBatchConfig::default());
    let tagged = Client::new(&c.proxy_addr()).with_tenant("alpha").with_priority("interactive");
    tagged.put("b", "o1", &payload(8 << 10, qos_seed())).unwrap();
    let req = BatchRequest::new(vec![BatchEntry::obj("b", "o1")]);
    assert_eq!(tagged.get_batch_collect(&req).unwrap().len(), 1);

    let legacy = Client::new(&c.proxy_addr());
    assert_eq!(legacy.get_batch_collect(&req).unwrap().len(), 1);

    let t = &c.targets[0];
    let text = t.metrics.render(&t.info.id);
    assert!(text.contains("tenant_admits_total"), "per-tenant family missing:\n{text}");
    assert!(text.contains("tenant=\"alpha\""), "tagged tenant line missing:\n{text}");
    assert!(
        text.contains(&format!("tenant=\"{}\"", wire::DEFAULT_TENANT)),
        "legacy traffic not accounted under the default tenant:\n{text}"
    );
}
