//! Property-based tests over the system's core invariants, using the
//! in-crate mini property framework (testutil::prop).

use getbatch::batch::request::{BatchEntry, BatchRequest};
use getbatch::cluster::placement;
use getbatch::cluster::smap::{NodeInfo, Smap};
use getbatch::tar;
use getbatch::testutil::prop::{bytes_gen, check, name_gen, PropConfig};
use getbatch::util::json::Value;
use getbatch::util::rng::Rng;
use getbatch::util::stats::Samples;

fn smap(n: usize) -> Smap {
    Smap::new(
        1,
        vec![],
        (0..n)
            .map(|i| NodeInfo {
                id: format!("t{i}"),
                http_addr: String::new(),
                p2p_addr: String::new(),
            })
            .collect(),
    )
}

#[test]
fn prop_tar_roundtrip_arbitrary_entries() {
    check(
        PropConfig { cases: 48, ..Default::default() },
        |rng: &mut Rng, size: usize| {
            let n = rng.usize_below(8) + 1;
            (0..n)
                .map(|i| tar::Entry {
                    name: format!("{}-{i}", name_gen(rng, 30)),
                    data: bytes_gen(rng, size * 40 + 1),
                })
                .collect::<Vec<_>>()
        },
        |entries| {
            let bytes = tar::write_archive(entries).map_err(|e| e.to_string())?;
            if bytes.len() % 512 != 0 {
                return Err("not block aligned".into());
            }
            let back = tar::read_archive(&bytes).map_err(|e| e.to_string())?;
            if &back != entries {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tar_member_index_matches_payload() {
    check(
        PropConfig { cases: 32, ..Default::default() },
        |rng: &mut Rng, size: usize| {
            let n = rng.usize_below(6) + 1;
            (0..n)
                .map(|i| tar::Entry { name: format!("m{i}"), data: bytes_gen(rng, size * 60 + 1) })
                .collect::<Vec<_>>()
        },
        |entries| {
            let bytes = tar::write_archive(entries).map_err(|e| e.to_string())?;
            let idx = tar::index_members(&bytes).map_err(|e| e.to_string())?;
            for e in entries {
                let &(off, len) = idx.get(&e.name).ok_or("member missing from index")?;
                if &bytes[off as usize..(off + len) as usize] != &e.data[..] {
                    return Err(format!("payload mismatch for {}", e.name));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_arbitrary_values() {
    fn gen_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.bool(0.5)),
            2 => Value::Num((rng.below(1 << 40) as f64) - (1u64 << 39) as f64),
            3 => Value::Str(name_gen(rng, 24)),
            4 => Value::Arr((0..rng.usize_below(4)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => {
                let mut o = Value::obj();
                for _ in 0..rng.usize_below(4) {
                    o = o.set(&name_gen(rng, 10), gen_value(rng, depth - 1));
                }
                o
            }
        }
    }
    check(
        PropConfig { cases: 64, ..Default::default() },
        |rng: &mut Rng, _size| gen_value(rng, 3),
        |v| {
            let text = v.to_string();
            let back = Value::parse(&text).map_err(|e| e.to_string())?;
            if &back != v {
                return Err(format!("{text} reparsed differently"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_placement_partition_complete_and_disjoint() {
    check(
        PropConfig { cases: 32, ..Default::default() },
        |rng: &mut Rng, size: usize| {
            let nodes = rng.usize_below(15) + 1;
            let entries: Vec<BatchEntry> = (0..size * 3 + 1)
                .map(|_| {
                    if rng.bool(0.4) {
                        BatchEntry::member("b", &name_gen(rng, 16), &name_gen(rng, 12))
                    } else {
                        BatchEntry::obj("b", &name_gen(rng, 16))
                    }
                })
                .collect();
            (nodes, entries)
        },
        |(nodes, entries)| {
            let s = smap(*nodes);
            let req = BatchRequest::new(entries.clone());
            let mut owned = vec![0usize; entries.len()];
            for t in 0..*nodes {
                for (i, _) in placement::local_entries(&s, &req, t) {
                    owned[i as usize] += 1;
                }
            }
            if owned.iter().any(|&c| c != 1) {
                return Err(format!("ownership counts {owned:?}"));
            }
            // weights agree with the partition
            let w = placement::placement_weights(&s, &req);
            if w.iter().map(|&x| x as usize).sum::<usize>() != entries.len() {
                return Err("weights don't sum".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batch_request_wire_roundtrip() {
    check(
        PropConfig { cases: 48, ..Default::default() },
        |rng: &mut Rng, size: usize| {
            let entries: Vec<BatchEntry> = (0..rng.usize_below(size + 1) + 1)
                .map(|_| {
                    if rng.bool(0.5) {
                        BatchEntry::member(&name_gen(rng, 8), &name_gen(rng, 20), &name_gen(rng, 20))
                    } else {
                        BatchEntry::obj(&name_gen(rng, 8), &name_gen(rng, 20))
                    }
                })
                .collect();
            BatchRequest::new(entries)
                .continue_on_err(rng.bool(0.5))
                .streaming(rng.bool(0.5))
        },
        |req| {
            let back = BatchRequest::from_body(&req.to_body()).ok_or("parse failed")?;
            if back.entries != req.entries
                || back.opts.continue_on_err != req.opts.continue_on_err
                || back.opts.streaming != req.opts.streaming
            {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_percentiles_monotone_and_bounded() {
    check(
        PropConfig { cases: 48, ..Default::default() },
        |rng: &mut Rng, size: usize| {
            (0..size * 5 + 1).map(|_| rng.f64() * 1e4).collect::<Vec<f64>>()
        },
        |xs| {
            let mut s = Samples::new();
            for &x in xs {
                s.add(x);
            }
            let (p50, p95, p99) = (s.percentile(50.0), s.percentile(95.0), s.percentile(99.0));
            let (lo, hi) = (s.min(), s.max());
            if !(lo <= p50 && p50 <= p95 && p95 <= p99 && p99 <= hi) {
                return Err(format!("not monotone: {lo} {p50} {p95} {p99} {hi}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_order_buffer_interleaved_chunk_fills_reassemble_exactly() {
    // Many producer threads, each owning a disjoint set of slots, deliver
    // their entries as chunk sequences of arbitrary sizes (some as whole
    // fills); the consumer streams slots 0..n in order. Whatever the
    // interleaving, every payload must reassemble byte-identical and in
    // strict slot order, and the buffer must end drained.
    use getbatch::dt::order::{ChunkWait, OrderBuffer};
    use std::sync::Arc;

    check(
        PropConfig { cases: 24, ..Default::default() },
        |rng: &mut Rng, size: usize| {
            let n_slots = rng.usize_below(12) + 1;
            let n_producers = rng.usize_below(4) + 1;
            let payloads: Vec<Vec<u8>> = (0..n_slots)
                .map(|_| bytes_gen(rng, size * 200 + 1))
                .collect();
            // per-slot chunk size (1..=len+1 → some single-chunk, some many)
            let chunk_sizes: Vec<usize> = payloads
                .iter()
                .map(|p| rng.usize_below(p.len() + 1) + 1)
                .collect();
            (n_producers, payloads, chunk_sizes)
        },
        |(n_producers, payloads, chunk_sizes)| {
            let buf = Arc::new(OrderBuffer::new(payloads.len()));
            std::thread::scope(|s| {
                for p in 0..*n_producers {
                    let buf = Arc::clone(&buf);
                    let payloads = &payloads;
                    let chunk_sizes = &chunk_sizes;
                    s.spawn(move || {
                        for idx in (p..payloads.len()).step_by(*n_producers) {
                            let data = &payloads[idx];
                            let cs = chunk_sizes[idx];
                            if data.len() <= cs {
                                buf.fill(idx as u32, data.clone());
                            } else {
                                let total = data.len() as u64;
                                let mut off = 0;
                                while off < data.len() {
                                    let end = (off + cs).min(data.len());
                                    buf.append_chunk(
                                        idx as u32,
                                        total,
                                        data[off..end].to_vec(),
                                        off == 0,
                                        end == data.len(),
                                    );
                                    off = end;
                                }
                            }
                        }
                    });
                }
                // consumer: strict-order streaming drain
                for (idx, want) in payloads.iter().enumerate() {
                    let mut got = Vec::new();
                    loop {
                        match buf.wait_chunk(idx as u32, std::time::Duration::from_secs(5)) {
                            ChunkWait::Chunk { bytes, total, done } => {
                                if total != want.len() as u64 {
                                    return Err(format!(
                                        "slot {idx}: declared {total} != {}",
                                        want.len()
                                    ));
                                }
                                got.extend_from_slice(&bytes);
                                if done {
                                    break;
                                }
                            }
                            other => return Err(format!("slot {idx}: {other:?}")),
                        }
                    }
                    if &got != want {
                        return Err(format!(
                            "slot {idx}: reassembly mismatch ({} vs {} bytes)",
                            got.len(),
                            want.len()
                        ));
                    }
                }
                Ok(())
            })?;
            if buf.buffered_bytes() != 0 {
                return Err(format!("residual bytes: {}", buf.buffered_bytes()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_chunk_frames_roundtrip_any_chunk_size() {
    // Frame-level chunking: any (payload, chunk size) must encode to a
    // frame sequence that decodes back byte-identically, with per-chunk CRC
    // verified on the way (read_frame checks it).
    use getbatch::proto::frame::{chunk_frames, read_frame, write_frame};

    check(
        PropConfig { cases: 40, ..Default::default() },
        |rng: &mut Rng, size: usize| {
            let payload = bytes_gen(rng, size * 120 + 1);
            let chunk = rng.usize_below(payload.len() + 2) + 1;
            (payload, chunk)
        },
        |(payload, chunk)| {
            let frames = chunk_frames(3, 9, payload.clone(), *chunk);
            let mut wire = Vec::new();
            for f in &frames {
                write_frame(&mut wire, f).map_err(|e| e.to_string())?;
            }
            let mut cur = std::io::Cursor::new(&wire);
            let mut rebuilt = Vec::new();
            let mut declared = None;
            let mut last_seen = false;
            while let Some(f) = read_frame(&mut cur).map_err(|e| e.to_string())? {
                if last_seen {
                    return Err("frame after LAST".into());
                }
                let (total, bytes) =
                    f.chunk_parts().ok_or("malformed first chunk")?;
                if f.is_first() {
                    declared = Some(total);
                }
                rebuilt.extend_from_slice(bytes);
                last_seen = f.is_last();
            }
            if !last_seen {
                return Err("no LAST frame".into());
            }
            if declared != Some(payload.len() as u64) {
                return Err(format!("declared {declared:?} != {}", payload.len()));
            }
            if &rebuilt != payload {
                return Err("payload mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_versioned_cache_matches_oracle_and_respects_capacity() {
    // The versioned read-through chunk cache under random PUT / GET /
    // DELETE interleavings over a small object pool: resident bytes must
    // never exceed `cache_bytes`, and every GET must agree with a plain
    // HashMap oracle — byte-identical for live objects, NotFound for
    // deleted ones. Overwrites are the interesting part: every PUT bumps
    // the version, so a stale chunk surviving in cache would diverge from
    // the oracle immediately.
    use getbatch::store::{Backend, CachedBackend, ChunkCache, LocalBackend, StoreError};
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[derive(Debug, Clone)]
    enum Op {
        Put(usize, Vec<u8>),
        Get(usize),
        Delete(usize),
    }

    static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

    check(
        PropConfig { cases: 10, ..Default::default() },
        |rng: &mut Rng, size: usize| {
            let chunk = 64usize << rng.usize_below(4); // 64 B .. 512 B
            let cache_bytes = (chunk * (1 + rng.usize_below(6))) as u64; // 1..=6 chunks
            let ops: Vec<Op> = (0..size.clamp(4, 60))
                .map(|_| {
                    let obj = rng.usize_below(4);
                    match rng.usize_below(6) {
                        0 | 1 => {
                            let len = rng.usize_below(3 * chunk + 1);
                            let mut data = vec![0u8; len];
                            rng.fill_bytes(&mut data);
                            Op::Put(obj, data)
                        }
                        5 => Op::Delete(obj),
                        _ => Op::Get(obj),
                    }
                })
                .collect();
            (chunk, cache_bytes, ops)
        },
        |(chunk, cache_bytes, ops)| {
            let base = std::env::temp_dir().join(format!(
                "gbprop-vcache-{}-{}",
                std::process::id(),
                DIR_SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            let _ = std::fs::remove_dir_all(&base);
            std::fs::create_dir_all(&base).map_err(|e| e.to_string())?;
            let local = Arc::new(LocalBackend::open(&base, 1).map_err(|e| e.to_string())?);
            let cache = Arc::new(ChunkCache::new(*cache_bytes, *chunk, None));
            let cached = CachedBackend::new(
                local as Arc<dyn Backend>,
                Arc::clone(&cache),
                1,
                Duration::ZERO, // revalidate every open: versions do the work
            );
            let mut oracle: HashMap<usize, Vec<u8>> = HashMap::new();
            let result = (|| -> Result<(), String> {
                for (k, op) in ops.iter().enumerate() {
                    match op {
                        Op::Put(obj, data) => {
                            cached
                                .put("b", &format!("o{obj}"), data)
                                .map_err(|e| format!("op {k} put o{obj}: {e}"))?;
                            oracle.insert(*obj, data.clone());
                        }
                        Op::Get(obj) => {
                            let got = cached
                                .open_entry("b", &format!("o{obj}"))
                                .and_then(|r| r.read_all());
                            match (got, oracle.get(obj)) {
                                (Ok(bytes), Some(want)) => {
                                    if &bytes != want {
                                        return Err(format!(
                                            "op {k}: o{obj} diverged from oracle \
                                             ({} vs {} bytes)",
                                            bytes.len(),
                                            want.len()
                                        ));
                                    }
                                }
                                (Err(StoreError::NotFound(_)), None) => {}
                                (Ok(_), None) => {
                                    return Err(format!("op {k}: deleted o{obj} still readable"))
                                }
                                (Err(e), Some(_)) => {
                                    return Err(format!("op {k}: live o{obj} failed: {e}"))
                                }
                                (Err(e), None) => {
                                    return Err(format!("op {k}: absent o{obj} wrong error: {e}"))
                                }
                            }
                        }
                        Op::Delete(obj) => {
                            match (cached.delete("b", &format!("o{obj}")), oracle.remove(obj)) {
                                (Ok(()), Some(_)) => {}
                                (Err(StoreError::NotFound(_)), None) => {}
                                (r, was) => {
                                    return Err(format!(
                                        "op {k}: delete o{obj} mismatch \
                                         (oracle had it: {}, got {r:?})",
                                        was.is_some()
                                    ))
                                }
                            }
                        }
                    }
                    if cache.resident_bytes() > *cache_bytes {
                        return Err(format!(
                            "op {k}: resident {} exceeds cache_bytes {cache_bytes}",
                            cache.resident_bytes()
                        ));
                    }
                }
                Ok(())
            })();
            let _ = std::fs::remove_dir_all(&base);
            result
        },
    );
}

#[test]
fn prop_epoch_plan_permutation_and_rank_shards_partition() {
    // The PR 8 epoch shuffle, as properties: for arbitrary (manifest
    // length, batch size, seed, epoch) the plan's batches concatenate to a
    // true permutation of 0..n (every sample exactly once, full batches
    // except possibly the last), recomputing the plan is deterministic,
    // and the rank-sharded slices `i ≡ r (mod world)` partition the batch
    // index space exactly — no batch dropped, none served twice.
    use getbatch::client::loader::EpochPlan;

    check(
        PropConfig { cases: 48, ..Default::default() },
        |rng: &mut Rng, size: usize| {
            let n = rng.usize_below(size * 8 + 16) + 1;
            let batch = rng.usize_below(9) + 1;
            let world = rng.usize_below(5) + 1;
            (n, batch, world, rng.below(1 << 48), rng.below(64))
        },
        |&(n, batch, world, seed, epoch)| {
            let plan = EpochPlan::new(n, batch, seed, epoch);
            let mut flat = Vec::with_capacity(n);
            for i in 0..plan.n_batches() {
                let b = plan.batch(i).ok_or("n_batches lied")?;
                if b.is_empty() {
                    return Err(format!("batch {i} is empty"));
                }
                if i + 1 < plan.n_batches() && b.len() != batch {
                    return Err(format!(
                        "non-final batch {i} has {} samples, want {batch}",
                        b.len()
                    ));
                }
                flat.extend_from_slice(b);
            }
            let mut sorted = flat;
            sorted.sort_unstable();
            if sorted != (0..n).collect::<Vec<_>>() {
                return Err(format!("batches are not a permutation of 0..{n}"));
            }
            let again = EpochPlan::new(n, batch, seed, epoch);
            for i in 0..plan.n_batches() {
                if plan.batch(i) != again.batch(i) {
                    return Err(format!("recomputed plan differs at batch {i}"));
                }
            }
            let mut claimed = vec![0u32; plan.n_batches()];
            for r in 0..world {
                for &i in &plan.rank_batches(r, world) {
                    if i % world != r {
                        return Err(format!(
                            "rank {r} of {world} claimed batch {i} (≢ {r} mod {world})"
                        ));
                    }
                    claimed[i] += 1;
                }
            }
            if claimed.iter().any(|&c| c != 1) {
                return Err(format!("rank shards are not a partition: {claimed:?}"));
            }
            Ok(())
        },
    );
}

/// The bench-manifest gate: the scenario list recorded in
/// `BENCH_hotpath.json` must match the `bench("…")` calls of
/// `rust/benches/hotpath.rs` exactly — same names, same order — so a
/// scenario added, renamed, or dropped without updating the recorded
/// series fails CI instead of silently desynchronizing the benchmark
/// record from the code.
#[test]
fn bench_manifest_matches_hotpath_scenarios() {
    let manifest =
        Value::parse(include_str!("../../BENCH_hotpath.json")).expect("BENCH_hotpath.json parses");
    let recorded: Vec<String> = manifest
        .get("scenarios")
        .and_then(|s| s.as_arr())
        .expect("scenarios array present")
        .iter()
        .map(|s| s.str_field("name").expect("scenario has a name").to_string())
        .collect();

    let mut in_source = Vec::new();
    for line in include_str!("../benches/hotpath.rs").lines() {
        if let Some(rest) = line.trim_start().strip_prefix("bench(\"") {
            let name = rest.split('"').next().unwrap();
            in_source.push(name.to_string());
        }
    }
    assert!(!in_source.is_empty(), "no bench(\"…\") calls found in hotpath.rs");
    assert_eq!(
        recorded, in_source,
        "BENCH_hotpath.json scenarios drifted from rust/benches/hotpath.rs — \
         regenerate the recorded series (scripts/record_hotpath.sh) when \
         adding, renaming, or removing a bench"
    );
}

#[test]
fn prop_hrw_stability_under_node_addition() {
    // adding a node must move only keys that now rank it first
    check(
        PropConfig { cases: 24, ..Default::default() },
        |rng: &mut Rng, size: usize| {
            let n = rng.usize_below(10) + 2;
            let keys: Vec<String> = (0..size * 4 + 4).map(|_| name_gen(rng, 20)).collect();
            (n, keys)
        },
        |(n, keys)| {
            let before = smap(*n);
            let after = smap(*n + 1);
            for k in keys {
                let key = format!("b/{k}");
                let o1 = placement::owner(&before, &key);
                let o2 = placement::owner(&after, &key);
                if o2 != o1 && o2 != *n {
                    return Err(format!("{key} moved {o1}->{o2} not to the new node"));
                }
            }
            Ok(())
        },
    );
}
