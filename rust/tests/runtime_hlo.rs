//! Runtime ⇄ artifact integration: load the AOT HLO produced by
//! python/compile/aot.py into the PJRT CPU client, execute init/collate/
//! train_step, and train end-to-end on cluster-fetched data.
//!
//! These tests are skipped (cleanly) when artifacts/ hasn't been built:
//! run `make artifacts` first. CI runs them via `make test`.

use getbatch::client::loader::{AccessMode, DataLoader};
use getbatch::client::sdk::Client;
use getbatch::runtime::pjrt::{tokens_from_samples, Runtime};
use getbatch::runtime::trainer;
use getbatch::testutil::fixtures;

fn runtime() -> Option<Runtime> {
    let dir = trainer::artifacts_dir().ok()?;
    Some(Runtime::load(&dir).expect("artifacts present but unloadable"))
}

macro_rules! require_artifacts {
    () => {
        match runtime() {
            Some(rt) => rt,
            None => {
                eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn init_params_have_expected_arity() {
    let rt = require_artifacts!();
    let params = rt.init_params(0).unwrap();
    assert_eq!(params.len(), rt.meta.n_param_tensors);
}

#[test]
fn init_is_deterministic_per_seed() {
    let rt = require_artifacts!();
    let a = rt.init_params(7).unwrap();
    let b = rt.init_params(7).unwrap();
    let c = rt.init_params(8).unwrap();
    let va = a[0].to_vec::<f32>().unwrap();
    let vb = b[0].to_vec::<f32>().unwrap();
    let vc = c[0].to_vec::<f32>().unwrap();
    assert_eq!(va, vb);
    assert_ne!(va, vc);
}

#[test]
fn collate_gathers_and_masks() {
    let rt = require_artifacts!();
    let samples: Vec<Vec<u8>> = (0..rt.meta.batch).map(|i| vec![(i + 1) as u8; 5 + i]).collect();
    let (flat, offsets) = tokens_from_samples(&rt.meta, &samples);
    let (batch, mask) = rt.collate(&flat, &offsets).unwrap();
    let b = batch.to_vec::<i32>().unwrap();
    let m = mask.to_vec::<f32>().unwrap();
    assert_eq!(b.len(), rt.meta.batch * rt.meta.seq_len);
    assert_eq!(m.len(), b.len());
    // row 0: five 1s then padding
    let t = rt.meta.seq_len;
    assert_eq!(&b[..5], &[1, 1, 1, 1, 1]);
    assert_eq!(b[5], rt.meta.pad_id);
    assert_eq!(&m[..5], &[1.0; 5]);
    assert_eq!(m[5], 0.0);
    let _ = t;
}

#[test]
fn train_step_executes_and_loss_finite() {
    let rt = require_artifacts!();
    let params = rt.init_params(1).unwrap();
    let samples: Vec<Vec<u8>> =
        (0..rt.meta.batch).map(|_| b"hello world hello world".to_vec()).collect();
    let (flat, offsets) = tokens_from_samples(&rt.meta, &samples);
    let (batch, mask) = rt.collate(&flat, &offsets).unwrap();
    let (new_params, loss) = rt.train_step(params, batch, mask).unwrap();
    assert_eq!(new_params.len(), rt.meta.n_param_tensors);
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
}

#[test]
fn training_on_repetitive_data_reduces_loss() {
    let rt = require_artifacts!();
    let mut params = rt.init_params(2).unwrap();
    // memorizable pattern
    let samples: Vec<Vec<u8>> = (0..rt.meta.batch)
        .map(|_| b"abcabcabcabcabcabcabcabcabcabcabcabc".to_vec())
        .collect();
    let (flat, offsets) = tokens_from_samples(&rt.meta, &samples);
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 0..30 {
        let (batch, mask) = rt.collate(&flat, &offsets).unwrap();
        let (p, loss) = rt.train_step(params, batch, mask).unwrap();
        params = p;
        if step == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first * 0.7, "loss {first} -> {last}");
}

#[test]
fn end_to_end_train_via_getbatch_cluster() {
    let rt = require_artifacts!();
    let c = fixtures::cluster(3);
    let manifest = fixtures::stage_shards(&c, "corpus", 4, 16, 512.0, 33);
    let mut loader = DataLoader::new(
        Client::new(&c.proxy_addr()),
        manifest,
        AccessMode::GetBatch,
        rt.meta.batch,
        9,
    );
    let report = trainer::train(&rt, &mut loader, 8, 0).unwrap();
    assert_eq!(report.losses.len(), 8);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    assert!(report.load_ms.n == 8 && report.step_ms.n == 8);
}
