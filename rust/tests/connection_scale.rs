//! Connection-scale tests for the readiness-driven transport: hundreds of
//! concurrent keep-alive clients multiplexed over a 2-thread reactor, the
//! bounded-write-buffer (backpressure) invariant under a deliberately slow
//! reader, and a budget-bounded end-to-end regression over the new
//! transport. Run in release in CI (`--test connection_scale`).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use getbatch::batch::request::{BatchEntry, BatchRequest};
use getbatch::client::sdk::Client;
use getbatch::cluster::node::Cluster;
use getbatch::config::{ClusterConfig, GetBatchConfig};
use getbatch::proto::http::{Handler, HttpClient, HttpServer, Request, Response};
use getbatch::transport::ReactorConfig;

/// A deterministic per-(client, round) payload so every echo is
/// byte-checkable without shared state.
fn payload(client: usize, round: usize) -> Vec<u8> {
    let len = 512 + (client * 37 + round * 101) % 3072;
    (0..len)
        .map(|i| ((i * 31 + client * 7 + round * 13) % 251) as u8)
        .collect()
}

/// ISSUE 6 acceptance: >= 500 concurrent keep-alive connections served
/// byte-correctly by a reactor with exactly 2 event-loop threads, proven
/// via the `open_connections` high-water mark.
#[test]
fn five_hundred_keepalive_clients_two_reactor_threads() {
    const CLIENTS: usize = 512;
    let handler: Handler = Arc::new(|req: Request| Response::ok(req.body));
    let srv = HttpServer::serve_opts(
        handler,
        "scale",
        ReactorConfig { threads: 2, max_connections: 2048, min_workers: 8, ..Default::default() },
    )
    .unwrap();
    let addr = srv.addr.to_string();
    let stats = srv.stats();

    let barrier = Arc::new(Barrier::new(CLIENTS));
    let mut joins = Vec::with_capacity(CLIENTS);
    for c in 0..CLIENTS {
        let addr = addr.clone();
        let barrier = Arc::clone(&barrier);
        joins.push(std::thread::spawn(move || {
            let client = HttpClient::new(true); // keep-alive: conn pools after use
            let p0 = payload(c, 0);
            let resp = client.request("POST", &addr, "/echo", &p0).unwrap();
            assert_eq!(resp.status, 200, "client {c} round 0");
            assert_eq!(resp.into_bytes().unwrap(), p0, "client {c} round 0 bytes");
            // Everyone holds their (pooled, still-open) connection here, so
            // all CLIENTS connections are open on the server simultaneously.
            barrier.wait();
            let p1 = payload(c, 1);
            let resp = client.request("POST", &addr, "/echo", &p1).unwrap();
            assert_eq!(resp.status, 200, "client {c} round 1");
            assert_eq!(resp.into_bytes().unwrap(), p1, "client {c} round 1 bytes");
            barrier.wait();
            // client drops here -> pooled connection closes
        }));
    }
    for j in joins {
        j.join().unwrap();
    }

    let peak = stats.open_connections_peak.get();
    assert!(peak >= CLIENTS as i64, "connection high-water {peak} < {CLIENTS}");
    assert_eq!(stats.shed.get(), 0, "no accepted connection was shed");
    assert!(stats.wakeups.get() > 0, "reactor loops actually woke");

    // Closes are detected by the reactor (EOF -> deregister): the gauge
    // must drain back toward zero without the server being dropped.
    let t0 = Instant::now();
    while stats.open_connections.get() > 0 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(stats.open_connections.get(), 0, "all client connections reaped");
}

/// The bounded-buffering invariant, observable: a streaming response to a
/// deliberately slow reader must never buffer more than the configured
/// write high-water mark (here tied to `dt_buffer_bytes`) plus one write
/// piece — the reactor toggles write interest instead of letting the
/// producer run ahead of the socket.
#[test]
fn slow_reader_write_backpressure_bounds_buffering() {
    const DT_BUFFER_BYTES: usize = 256 << 10;
    const PIECE: usize = 16 << 10;
    const TOTAL: usize = 8 << 20;
    let handler: Handler = Arc::new(|_req: Request| {
        Response::stream(|w| {
            let piece = vec![0xA5u8; PIECE];
            let mut sent = 0;
            while sent < TOTAL {
                w.write_all(&piece)?;
                sent += PIECE;
            }
            Ok(())
        })
    });
    let srv = HttpServer::serve_opts(
        handler,
        "slow-reader",
        ReactorConfig {
            threads: 1,
            // High-water at half the budget: even with one in-flight write
            // piece on top, buffering stays strictly under dt_buffer_bytes.
            write_buf_limit: DT_BUFFER_BYTES / 2,
            ..Default::default()
        },
    )
    .unwrap();
    let stats = srv.stats();

    let mut conn = TcpStream::connect(srv.addr).unwrap();
    conn.write_all(b"GET /big HTTP/1.1\r\nhost: t\r\ncontent-length: 0\r\n\r\n").unwrap();
    // Read slowly: small pieces with pauses, many times slower than the
    // producer can fill, until the chunked terminator arrives.
    let mut tail: Vec<u8> = Vec::new();
    let mut got = 0usize;
    let mut buf = vec![0u8; 8 << 10];
    loop {
        let n = conn.read(&mut buf).unwrap();
        assert!(n > 0, "connection closed before the chunked terminator");
        got += n;
        tail.extend_from_slice(&buf[..n]);
        if tail.len() > 16 {
            tail.drain(..tail.len() - 16);
        }
        if tail.ends_with(b"0\r\n\r\n") {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(got >= TOTAL, "full body delivered despite backpressure ({got} bytes)");
    let peak = stats.peak_outbuf.get();
    assert!(peak > 0, "some bytes were buffered");
    assert!(
        peak <= DT_BUFFER_BYTES as i64,
        "peak per-connection write buffer {peak} exceeded dt_buffer_bytes {DT_BUFFER_BYTES}"
    );
}

/// Budget-bounded end-to-end over the new transport: same falsifiable
/// claim as the cluster_e2e original (payload >> DT memory budget, strict
/// order, byte-identical, budget never overrun), re-run with the reactor
/// shape pinned (2 event-loop threads, bounded connections).
#[test]
fn budget_bounded_streaming_batch_over_reactor_transport() {
    let cfg = ClusterConfig {
        targets: 3,
        reactor_threads: 2,
        max_connections: 256,
        getbatch: GetBatchConfig {
            chunk_bytes: 64 << 10,
            dt_buffer_bytes: 256 << 10,
            ..Default::default()
        },
        ..Default::default()
    };
    let c = Cluster::start(cfg).unwrap();
    let mut rng = getbatch::util::rng::Rng::new(0xC0DE);
    let mut want = Vec::new();
    for i in 0..6 {
        let mut data = vec![0u8; 512 << 10];
        rng.fill_bytes(&mut data);
        c.put_direct("b", &format!("big-{i}"), &data).unwrap();
        want.push(data);
    }

    let client = Client::new(&c.proxy_addr());
    let entries: Vec<BatchEntry> =
        (0..6).map(|i| BatchEntry::obj("b", &format!("big-{i}"))).collect();
    let items =
        client.get_batch_collect(&BatchRequest::new(entries).streaming(true)).unwrap();

    assert_eq!(items.len(), 6);
    for (i, item) in items.iter().enumerate() {
        assert_eq!(item.name(), format!("big-{i}"), "strict order at position {i}");
        assert_eq!(item.data().unwrap(), &want[i][..], "entry {i} byte-identical");
    }
    for t in &c.targets {
        assert!(
            t.budget.peak() <= t.budget.budget(),
            "target {}: peak resident {} exceeded budget {}",
            t.info.id,
            t.budget.peak(),
            t.budget.budget()
        );
        assert_eq!(t.budget.overruns(), 0, "target {}: forced admissions", t.info.id);
    }
}
