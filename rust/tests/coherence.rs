//! Cluster-wide cache coherence under overwrite-heavy workloads: versioned
//! chunk keys + the best-effort `/v1/invalidate` broadcast, proven against
//! the shapes that used to go stale — overwrite through one node / read
//! through another (cold *and* warm), delete visibility, a *missed*
//! broadcast corrected by versioned keys alone, the gateway-side
//! invalidation fan-out, and a concurrency property: no single read ever
//! interleaves bytes of two versions.
//!
//! The overwrite-race property reads its RNG seed from
//! `GETBATCH_COHERENCE_SEED` so CI can pin the interleavings it exercises.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use common::{payload, seeded_tempdir, serving_rb, start_cluster, sum};
use getbatch::batch::request::{BatchEntry, BatchRequest};
use getbatch::client::sdk::Client;
use getbatch::cluster::placement;
use getbatch::config::GetBatchConfig;
use getbatch::proto::http::HttpClient;
use getbatch::proto::wire;
use getbatch::store::{Backend, CachedBackend, ChunkCache, LocalBackend};
use getbatch::testutil::fixtures;
use getbatch::testutil::prop::{check, PropConfig};
use getbatch::util::rng::Rng;
use getbatch::Cluster;

/// Serving cluster: 2 targets fronting bucket `rb` from `storage_addr`
/// through each target's chunk cache, with the given coherence grace.
fn serving(storage_addr: &str, grace: Duration) -> Cluster {
    serving_rb(
        storage_addr,
        2,
        GetBatchConfig {
            chunk_bytes: 4 << 10,
            dt_buffer_bytes: 64 << 10,
            cache_bytes: 4 << 20,
            readahead_chunks: 1,
            coherence_grace: grace,
            ..Default::default()
        },
    )
}

fn batch_bytes(client: &Client, obj: &str) -> Vec<u8> {
    let items = client
        .get_batch_collect(&BatchRequest::new(vec![BatchEntry::obj("rb", obj)]))
        .unwrap();
    assert_eq!(items.len(), 1);
    items[0].data().expect("entry present").to_vec()
}

/// The acceptance scenario: overwrite through node A, GetBatch through the
/// cluster — the serving node (B, the entry's HRW owner, whose cache is
/// warm with the old version) must return the new bytes cold *and* warm,
/// with the stale chunks counted out under `cache_stale_evictions_total`.
/// Grace 0 keeps the test deterministic: every open revalidates, so the
/// result cannot depend on broadcast delivery timing.
#[test]
fn overwrite_through_node_a_reads_fresh_through_node_b_cold_and_warm() {
    let storage = fixtures::cluster(1);
    let v1 = payload(24 << 10, 11);
    storage.put_direct("rb", "o", &v1).unwrap();

    let c = serving(&storage.proxy_addr(), Duration::ZERO);
    let client = Client::new(&c.proxy_addr());

    // Cold then warm: v1, with the owner's cache serving the second read.
    assert_eq!(batch_bytes(&client, "o"), v1, "cold read");
    let hits_cold = sum(&c, |t| t.metrics.cache_hits.get());
    assert_eq!(batch_bytes(&client, "o"), v1, "warm read");
    assert!(sum(&c, |t| t.metrics.cache_hits.get()) > hits_cold, "second read was warm");

    // Overwrite *through the non-owner target* (node A): write-through to
    // storage + invalidation broadcast toward the warm owner (node B).
    let owner = placement::owner(&c.smap, "rb/o");
    let writer = 1 - owner;
    let v2 = payload(24 << 10, 12);
    let http = HttpClient::new(true);
    let resp = http.put(&c.target_addr(writer), &wire::object_path("rb", "o"), &v2).unwrap();
    assert_eq!(resp.status, 200);

    // The very next read — served by node B off its warm-but-stale cache
    // keys — must be v2: the new version makes every v1 chunk unreachable.
    assert_eq!(batch_bytes(&client, "o"), v2, "fresh bytes straight after the overwrite");
    assert!(
        sum(&c, |t| t.metrics.cache_stale_evictions.get()) > 0,
        "stale v1 chunks were evicted eagerly"
    );
    assert!(
        sum(&c, |t| t.metrics.invalidate_broadcasts.get()) >= 1,
        "the writing node broadcast the invalidation"
    );
    // And v2 is warm now.
    let hits_before = sum(&c, |t| t.metrics.cache_hits.get());
    assert_eq!(batch_bytes(&client, "o"), v2, "warm read of the new version");
    assert!(sum(&c, |t| t.metrics.cache_hits.get()) > hits_before, "v2 served from cache");
}

/// With a *long* grace, correctness-in-time is the broadcast's job: after
/// an overwrite through one node, the other node's warm cache converges to
/// the new bytes without ever re-probing (the lens entry is dropped by the
/// received `/v1/invalidate`, not by grace expiry).
#[test]
fn invalidation_broadcast_converges_warm_peers_within_grace() {
    let storage = fixtures::cluster(1);
    let v1 = payload(20 << 10, 21);
    storage.put_direct("rb", "o", &v1).unwrap();

    let c = serving(&storage.proxy_addr(), Duration::from_secs(60));
    let client = Client::new(&c.proxy_addr());
    assert_eq!(batch_bytes(&client, "o"), v1);
    assert_eq!(batch_bytes(&client, "o"), v1, "owner cache warm");

    let owner = placement::owner(&c.smap, "rb/o");
    let writer = 1 - owner;
    let v2 = payload(20 << 10, 22);
    let http = HttpClient::new(true);
    let resp = http.put(&c.target_addr(writer), &wire::object_path("rb", "o"), &v2).unwrap();
    assert_eq!(resp.status, 200);

    // The broadcast is fire-and-forget: poll until it lands. The 60 s
    // grace guarantees revalidation can NOT be what flips the answer. A
    // read that overlaps the invalidation may transiently fail (its pinned
    // version got superseded mid-read) — that is within contract; only the
    // converged result matters here.
    let mut converged = false;
    for _ in 0..200 {
        if let Ok(items) = client
            .get_batch_collect(&BatchRequest::new(vec![BatchEntry::obj("rb", "o")]))
        {
            if items[0].data() == Some(&v2[..]) {
                converged = true;
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    assert!(converged, "broadcast invalidation reached the warm owner");
    assert!(
        c.targets[owner].cache.invalidations.get() > 0,
        "owner processed a received invalidation"
    );
}

/// The missed-broadcast backstop: the underlying storage is mutated
/// *without the serving cluster hearing anything* (direct write to the
/// storage cluster — no `/v1/invalidate` can reach the serving smap). Once
/// the coherence grace expires, versioned chunk keys alone must bring every
/// node back to the bytes that exist — the acceptance criterion's
/// "versioned keys remain the correctness backstop".
#[test]
fn missed_broadcast_versioned_keys_keep_reads_byte_correct() {
    let storage = fixtures::cluster(1);
    let v1 = payload(24 << 10, 31);
    storage.put_direct("rb", "o", &v1).unwrap();

    let grace = Duration::from_millis(150);
    let c = serving(&storage.proxy_addr(), grace);
    let client = Client::new(&c.proxy_addr());
    assert_eq!(batch_bytes(&client, "o"), v1);
    assert_eq!(batch_bytes(&client, "o"), v1, "warm");

    // Out-of-band overwrite: straight into the storage cluster's store.
    let v2 = payload(24 << 10, 32);
    storage.put_direct("rb", "o", &v2).unwrap();

    std::thread::sleep(grace + Duration::from_millis(250));
    assert_eq!(
        batch_bytes(&client, "o"),
        v2,
        "post-grace revalidation observed the new version"
    );
    assert_eq!(
        sum(&c, |t| t.metrics.invalidate_broadcasts.get()),
        0,
        "no broadcast was involved — versioned keys did this alone"
    );
    assert!(sum(&c, |t| t.metrics.cache_stale_evictions.get()) > 0, "v1 chunks evicted");
    assert_eq!(batch_bytes(&client, "o"), v2, "new version warm afterwards");
}

/// Delete-through-one-node visibility: after a DELETE through the serving
/// cluster, a continue-on-error batch returns a placeholder (never stale
/// cached bytes), and non-placeholder entries are unaffected.
#[test]
fn delete_through_cluster_is_visible_despite_warm_caches() {
    let storage = fixtures::cluster(1);
    let keep = payload(8 << 10, 41);
    let doomed = payload(8 << 10, 42);
    storage.put_direct("rb", "keep", &keep).unwrap();
    storage.put_direct("rb", "doomed", &doomed).unwrap();

    let c = serving(&storage.proxy_addr(), Duration::ZERO);
    let client = Client::new(&c.proxy_addr());
    let req = BatchRequest::new(vec![
        BatchEntry::obj("rb", "keep"),
        BatchEntry::obj("rb", "doomed"),
    ])
    .continue_on_err(true);
    let items = client.get_batch_collect(&req).unwrap();
    assert_eq!(items[0].data().unwrap(), &keep[..]);
    assert_eq!(items[1].data().unwrap(), &doomed[..], "warm-up read");

    let http = HttpClient::new(true);
    let resp = http
        .request("DELETE", &c.proxy_addr(), &wire::object_path("rb", "doomed"), &[])
        .unwrap();
    assert_eq!(resp.status, 200);

    let items = client.get_batch_collect(&req).unwrap();
    assert_eq!(items[0].data().unwrap(), &keep[..], "surviving entry intact");
    assert!(
        items[1].is_missing(),
        "deleted object surfaced as a placeholder, not stale cached bytes"
    );
    assert!(sum(&c, |t| t.metrics.soft_errors.get()) > 0);
}

/// The gateway-side broadcast: one `POST /v1/invalidate` against a proxy
/// fans out to every target — how an external writer (who mutated storage
/// behind the cluster's back) drops a whole cluster's cached object at
/// once, without waiting out the grace.
#[test]
fn proxy_invalidate_fans_out_to_every_target() {
    // Local cached bucket, long grace: only the fan-out can flip the bytes.
    let c = start_cluster(
        2,
        4,
        GetBatchConfig {
            chunk_bytes: 4 << 10,
            cache_bytes: 1 << 20,
            coherence_grace: Duration::from_secs(60),
            buckets: vec![getbatch::config::BucketSpec {
                name: "hot".into(),
                backend: "local".into(),
                remote_addrs: Vec::new(),
                cache: true,
            }],
            ..Default::default()
        },
    );
    let client = Client::new(&c.proxy_addr());
    let v1 = payload(16 << 10, 51);
    c.put_direct("hot", "o", &v1).unwrap();

    let read = |tag: &str, want: &[u8]| {
        let items = client
            .get_batch_collect(&BatchRequest::new(vec![BatchEntry::obj("hot", "o")]))
            .unwrap();
        assert_eq!(items[0].data().unwrap(), want, "{tag}");
    };
    read("cold v1", &v1);
    read("warm v1", &v1);

    // Mutate behind the cache (direct local write — no HTTP, no broadcast):
    // with the 60 s grace the cluster keeps serving the remembered v1.
    let v2 = payload(16 << 10, 52);
    c.put_direct("hot", "o", &v2).unwrap();
    read("stale within grace (the gap the fan-out exists for)", &v1);

    // One call to the gateway drops it everywhere.
    let http = HttpClient::new(true);
    let resp = http
        .request("POST", &c.proxy_addr(), "/v1/invalidate?bucket=hot&obj=o", &[])
        .unwrap();
    assert_eq!(resp.status, 200);
    let body = String::from_utf8(resp.into_bytes().unwrap()).unwrap();
    assert!(body.contains("2/2"), "delivered to every target: {body}");
    assert!(c.proxies[0].state.metrics.invalidate_broadcasts.get() >= 1);

    read("fresh after fan-out", &v2);
    assert!(sum(&c, |t| t.metrics.cache_invalidations.get()) >= 2, "both targets invalidated");
}

/// The overwrite-race property (mini-prop, `testutil::prop`): under
/// concurrent out-of-band overwrites, a read through the cache either
/// fails (version superseded mid-read — allowed) or returns bytes of
/// exactly ONE version — never an interleaving. Every byte of version `k`
/// equals `k % 251`, so uniformity is the whole check. Seeded via
/// `GETBATCH_COHERENCE_SEED` (CI pins two seeds).
#[test]
fn prop_concurrent_overwrites_never_interleave_versions() {
    let seed = std::env::var("GETBATCH_COHERENCE_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0_FFEE);
    check(
        PropConfig { cases: 6, seed, max_shrink: 8 },
        |rng: &mut Rng, size: usize| {
            let chunk = 256usize << rng.usize_below(3); // 256 B .. 1 KiB
            let chunks = 2 + rng.usize_below(4); // 2..=5 chunks per object
            let writes = 8 + size.min(40);
            (chunk, chunk * chunks, writes)
        },
        |&(chunk, obj_len, writes)| overwrite_race(chunk, obj_len, writes),
    );
}

fn overwrite_race(chunk: usize, obj_len: usize, writes: usize) -> Result<(), String> {
    let base = seeded_tempdir("coh-race");
    let local = Arc::new(LocalBackend::open(&base, 1).map_err(|e| e.to_string())?);
    let cache = Arc::new(ChunkCache::new(1 << 20, chunk, None));
    let cached = Arc::new(CachedBackend::new(
        Arc::clone(&local) as Arc<dyn Backend>,
        cache,
        1,
        Duration::ZERO,
    ));
    // Version-tagged payloads: every byte of write k is k % 251.
    let pattern = |k: usize| vec![(k % 251) as u8; obj_len];
    cached.put("b", "o", &pattern(0)).map_err(|e| e.to_string())?;

    let stop = Arc::new(AtomicBool::new(false));
    let verdict = std::thread::scope(|s| -> Result<(), String> {
        // Out-of-band writer: straight into the local tier, worst case for
        // the cache (its own put() would at least invalidate locally).
        let writer = s.spawn(|| {
            for k in 1..=writes {
                local.put("b", "o", &pattern(k)).expect("writer put");
            }
        });
        let mut readers = Vec::new();
        for _ in 0..2 {
            let cached = Arc::clone(&cached);
            let stop = Arc::clone(&stop);
            readers.push(s.spawn(move || -> Result<(), String> {
                while !stop.load(Ordering::Relaxed) {
                    match cached.open_entry("b", "o").and_then(|r| r.read_all()) {
                        Ok(bytes) => {
                            if bytes.len() != obj_len {
                                return Err(format!(
                                    "read length {} != {obj_len}",
                                    bytes.len()
                                ));
                            }
                            let v = bytes[0];
                            if let Some(pos) = bytes.iter().position(|&b| b != v) {
                                return Err(format!(
                                    "interleaved versions: byte 0 is {v}, byte {pos} is {}",
                                    bytes[pos]
                                ));
                            }
                        }
                        // A failed read (version superseded mid-fill,
                        // metadata race) is within contract — only mixing
                        // is forbidden.
                        Err(_) => {}
                    }
                }
                Ok(())
            }));
        }
        writer.join().map_err(|_| "writer panicked".to_string())?;
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().map_err(|_| "reader panicked".to_string())??;
        }
        Ok(())
    });
    // Quiesced: the final read must succeed and be exactly the last write.
    let settled = verdict.and_then(|()| {
        let bytes = cached
            .open_entry("b", "o")
            .and_then(|r| r.read_all())
            .map_err(|e| format!("settled read failed: {e}"))?;
        if bytes != pattern(writes) {
            return Err("settled read is not the last version".to_string());
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&base);
    settled
}
