//! The tail-latency scenario family: hedged reads against a straggling
//! endpoint — byte-identity of hedged batches plus the hedge counters
//! moving, the headline P99 cut with hedging on vs off under identical
//! load, and the version pin failing a read closed when an overwrite races
//! a hedge/failover re-open.

mod common;

use std::collections::HashMap;
use std::io::{self, Write};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use common::{payload, retry_once, start_cluster, sum};
use getbatch::batch::request::{BatchEntry, BatchRequest};
use getbatch::client::sdk::Client;
use getbatch::config::GetBatchConfig;
use getbatch::proto::http::{
    range_unsatisfiable, resolve_range, serve_ranged_bytes_after, Handler, HttpServer, RangeSpec,
    Request, Response,
};
use getbatch::proto::wire;
use getbatch::store::{Backend, RemoteBackend};
use getbatch::util::crc32;

/// A controllable storage endpoint over an in-memory object map (keys
/// `bucket/obj`):
/// - `delay` is injected before serving any object request (the straggler
///   knob — settable mid-test, `/v1/health` stays instant);
/// - `version` stamps every object response with `x-getbatch-version`
///   (models one fixed write generation per stub);
/// - `die_after` makes ranged GETs deliver that many bytes then abort the
///   connection mid-stream (endpoint death mid-read).
struct StubEndpoint {
    addr: String,
    delay: Arc<Mutex<Duration>>,
    _srv: HttpServer,
}

fn stub_endpoint(
    objects: HashMap<String, Vec<u8>>,
    version: Option<u64>,
    die_after: Option<usize>,
) -> StubEndpoint {
    let objects = Arc::new(objects);
    let delay = Arc::new(Mutex::new(Duration::ZERO));
    let delay2 = Arc::clone(&delay);
    let handler: Handler = Arc::new(move |req: Request| {
        if req.path == wire::paths::HEALTH {
            return Response::ok(b"ok".to_vec());
        }
        let (bucket, obj) = match wire::parse_object_path(&req.path) {
            Some(x) => x,
            None => return Response::status(404),
        };
        if req.method != "GET" {
            return Response::status(400);
        }
        let data = match objects.get(&format!("{bucket}/{obj}")) {
            Some(d) => d.clone(),
            None => return Response::status(404),
        };
        let crc = crc32::hash(&data);
        let pause = *delay2.lock().unwrap();
        let resp = match die_after {
            None => serve_ranged_bytes_after(pause, &req, &data),
            Some(k) => {
                let len = data.len() as u64;
                match resolve_range(req.header("range"), len) {
                    RangeSpec::Slice { start, end } if (end - start) as usize > k => {
                        let partial = data[start as usize..start as usize + k].to_vec();
                        Response::stream(move |w| {
                            w.write_all(&partial)?;
                            w.flush()?;
                            Err(io::Error::new(io::ErrorKind::Other, "injected endpoint death"))
                        })
                        .into_partial(start, end, len)
                    }
                    RangeSpec::Slice { start, end } => {
                        Response::ok(data[start as usize..end as usize].to_vec())
                            .into_partial(start, end, len)
                    }
                    RangeSpec::Whole => Response::ok(data),
                    RangeSpec::Unsatisfiable => range_unsatisfiable(len),
                }
            }
        };
        let resp = resp.with_header(wire::HDR_OBJ_CRC, &format!("{crc:08x}"));
        match version {
            Some(v) => resp.with_header(wire::HDR_OBJ_VERSION, &v.to_string()),
            None => resp,
        }
    });
    let srv = HttpServer::serve(handler, 8, "stub-ep").unwrap();
    StubEndpoint { addr: srv.addr.to_string(), delay, _srv: srv }
}

fn stage(n: usize, bytes: usize, seed: u64) -> (HashMap<String, Vec<u8>>, Vec<(String, Vec<u8>)>) {
    let mut objects = HashMap::new();
    let mut staged = Vec::new();
    for i in 0..n {
        let name = format!("obj-{i:03}");
        let data = payload(bytes, seed + i as u64);
        objects.insert(format!("rb/{name}"), data.clone());
        staged.push((name, data));
    }
    (objects, staged)
}

#[test]
fn hedged_getbatch_is_byte_identical_and_the_backup_wins() {
    // One endpoint straggles (120 ms to first byte), the other is instant.
    // The straggler is listed FIRST so the cold round-robin pick lands on
    // it; with a 5 ms hedge floor every such read must be raced to the
    // fast endpoint, win there, and stay byte-identical.
    let (objects, staged) = stage(6, 40 << 10, 700);
    let slow = stub_endpoint(objects.clone(), Some(1), None);
    *slow.delay.lock().unwrap() = Duration::from_millis(120);
    let fast = stub_endpoint(objects, Some(1), None);

    let c = start_cluster(
        1,
        4,
        GetBatchConfig {
            chunk_bytes: 16 << 10,
            dt_buffer_bytes: 64 << 10,
            hedge_min: Duration::from_millis(5),
            // No slow-trial noise in this test: the probe window is huge.
            endpoint_probe: Duration::from_secs(60),
            ..Default::default()
        },
    );
    c.route_remote_bucket("rb", &[&slow.addr, &fast.addr], false);
    let client = Client::new(&c.proxy_addr());
    let entries: Vec<BatchEntry> = staged.iter().map(|(n, _)| BatchEntry::obj("rb", n)).collect();

    let items = client.get_batch_collect(&BatchRequest::new(entries)).unwrap();
    for (item, (name, data)) in items.iter().zip(&staged) {
        assert!(!item.is_missing(), "{name} must not degrade to a placeholder");
        assert_eq!(item.data().unwrap(), &data[..], "{name} byte-identical under hedging");
    }
    assert!(sum(&c, |t| t.metrics.hedges.get()) > 0, "straggling reads launched hedges");
    assert!(sum(&c, |t| t.metrics.hedge_wins.get()) > 0, "the fast endpoint won races");
    assert_eq!(sum(&c, |t| t.metrics.hard_failures.get()), 0, "no aborted requests");

    // The losing primaries eventually answer (120 ms later); their usable
    // responses are dropped and counted as canceled.
    let mut canceled = 0;
    for _ in 0..100 {
        canceled = sum(&c, |t| t.metrics.hedges_canceled.get());
        if canceled > 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(canceled > 0, "losing primaries counted as canceled hedges");
}

/// One load run for the P99 comparison: 4 reader threads x 50 single-entry
/// batches against a [slow, fast] endpoint pair, returning every read's
/// client-observed duration plus the run's hedge counter.
fn tail_run(hedge_quantile: f64) -> (Vec<Duration>, u64) {
    let (objects, staged) = stage(8, 8 << 10, 1300);
    let slow = stub_endpoint(objects.clone(), Some(1), None);
    *slow.delay.lock().unwrap() = Duration::from_millis(150);
    let fast = stub_endpoint(objects, Some(1), None);

    let c = start_cluster(
        1,
        8,
        GetBatchConfig {
            chunk_bytes: 16 << 10,
            dt_buffer_bytes: 64 << 10,
            // Past 50 ms EWMA the straggler is deprioritized (not opened);
            // it keeps getting one re-trial per 100 ms window.
            endpoint_slow: Duration::from_millis(50),
            endpoint_probe: Duration::from_millis(100),
            hedge_quantile,
            hedge_min: Duration::from_millis(25),
            ..Default::default()
        },
    );
    c.route_remote_bucket("rb", &[&slow.addr, &fast.addr], false);

    let staged = Arc::new(staged);
    let mut durations: Vec<Duration> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..4usize {
            let staged = Arc::clone(&staged);
            let proxy = c.proxy_addr();
            handles.push(s.spawn(move || {
                let client = Client::new(&proxy);
                let mut took = Vec::new();
                for i in 0..50usize {
                    let (name, data) = &staged[(t * 50 + i) % staged.len()];
                    let req = BatchRequest::new(vec![BatchEntry::obj("rb", name)]);
                    let t0 = Instant::now();
                    let items = client.get_batch_collect(&req).unwrap();
                    took.push(t0.elapsed());
                    assert_eq!(items[0].data().unwrap(), &data[..], "{name} byte-identical");
                }
                took
            }));
        }
        for h in handles {
            durations.extend(h.join().unwrap());
        }
    });
    let hedges = sum(&c, |t| t.metrics.hedges.get());
    (durations, hedges)
}

fn p99(mut v: Vec<Duration>) -> Duration {
    v.sort();
    v[(v.len() * 99) / 100]
}

#[test]
fn hedging_cuts_the_read_p99_under_a_straggling_endpoint() {
    // Identical load twice: hedging off (quantile 0.0), then on (0.95).
    // Unhedged, every pick of the straggler costs its full 150 ms delay,
    // so the P99 sits at the straggler's latency; hedged, those reads are
    // raced to the fast endpoint after the 25 ms floor and the P99 must
    // come down strictly. The comparison is timing-sensitive, so it runs
    // under the bounded retry-once guard: one CI scheduling hiccup is
    // absorbed, a real regression fails both attempts.
    retry_once("tail_latency::hedged_p99", 1300, || {
        let (unhedged, hedges_off) = tail_run(0.0);
        let (hedged, hedges_on) = tail_run(0.95);
        // Counter wiring is deterministic — a failure here is a real bug,
        // never a flake, so these stay hard asserts inside the guard.
        assert_eq!(hedges_off, 0, "quantile 0.0 disables hedging outright");
        assert!(hedges_on > 0, "the straggler forced hedges");

        let (p_off, p_on) = (p99(unhedged), p99(hedged));
        if p_off < Duration::from_millis(100) {
            return Err(format!(
                "unhedged P99 must feel the 150 ms straggler, got {p_off:?}"
            ));
        }
        if p_on >= p_off {
            return Err(format!(
                "hedging must cut the P99: hedged {p_on:?} vs unhedged {p_off:?}"
            ));
        }
        Ok(())
    });
}

#[test]
fn version_change_across_a_reopen_fails_closed() {
    // Endpoint A serves write generation 1 and dies 4 KiB into every
    // ranged body; endpoint B serves generation 2 with different bytes
    // (an overwrite landed on the store between A's stream and the
    // hedge/failover re-open). A read that started on A must surface the
    // version pin's InvalidData — never v1-prefix + v2-suffix bytes.
    let v1 = payload(64 << 10, 1);
    let v2 = payload(64 << 10, 2);
    let mut a_objects = HashMap::new();
    a_objects.insert("b/o".to_string(), v1);
    let mut b_objects = HashMap::new();
    b_objects.insert("b/o".to_string(), v2.clone());
    let a = stub_endpoint(a_objects, Some(1), Some(4 << 10));
    let b = stub_endpoint(b_objects, Some(2), None);

    let remote = RemoteBackend::multi(&[&a.addr, &b.addr], 10, Duration::from_millis(100), None);
    let mut saw_pin = false;
    for _ in 0..8 {
        let _ = remote.size("b", "o").unwrap(); // parity shift onto A
        match remote.open_entry("b", "o").unwrap().read_all() {
            // Stream served wholly by B: fine, and only generation 2.
            Ok(got) => assert_eq!(got, v2, "a clean stream must be pure v2"),
            // Stream started on A, re-opened on B: must fail closed.
            Err(e) => {
                let msg = e.to_string();
                assert!(
                    msg.contains("refusing to stitch bytes across versions"),
                    "unexpected error: {msg}"
                );
                saw_pin = true;
                break;
            }
        }
    }
    assert!(saw_pin, "a stitched read must trip the version pin");
}
